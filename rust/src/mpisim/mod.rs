//! Simulated MPI collectives: alpha-beta cost models over the torus.
//!
//! Used by the distributed-FFT baselines (FFT-MPI, heFFTe) and the step
//! model.  All costs are analytic — the *shape* (latency- vs bandwidth-
//! bound, scaling in P) is what Figs 8-10 depend on; constants come from
//! [`MachineConfig`].

use crate::config::MachineConfig;
use crate::tofu::Torus;

/// Point-to-point message: latency + per-hop penalty + serialization.
pub fn p2p_time(bytes: usize, hops: usize, m: &MachineConfig) -> f64 {
    m.p2p_latency + hops as f64 * m.hop_latency + bytes as f64 / m.link_bandwidth
}

/// Ring allgather over P ranks, each contributing `bytes_each`.
pub fn allgather_time(p: usize, bytes_each: usize, m: &MachineConfig) -> f64 {
    if p <= 1 {
        return 0.0;
    }
    (p - 1) as f64 * (m.p2p_latency + bytes_each as f64 / m.link_bandwidth)
}

/// Recursive-doubling allreduce of `bytes` over P ranks (software path;
/// the hardware BG path is [`crate::tofu::bg_allreduce_time`]).
pub fn allreduce_time(p: usize, bytes: usize, m: &MachineConfig) -> f64 {
    if p <= 1 {
        return 0.0;
    }
    (p as f64).log2().ceil() * (m.p2p_latency + bytes as f64 / m.link_bandwidth)
}

/// Pairwise-exchange alltoall: each rank sends `bytes_per_pair` to every
/// other rank.
pub fn alltoall_time(p: usize, bytes_per_pair: usize, m: &MachineConfig) -> f64 {
    if p <= 1 {
        return 0.0;
    }
    (p - 1) as f64 * (m.p2p_latency + bytes_per_pair as f64 / m.link_bandwidth)
}

/// Halo (ghost) exchange with the 6 face neighbours on the torus, each
/// message `bytes_per_face`, overlappable across the paper's 6 TNIs:
/// the faces go out concurrently, so cost ~ max over faces + one latency.
pub fn halo_time(bytes_per_face: usize, m: &MachineConfig) -> f64 {
    m.p2p_latency + m.hop_latency + bytes_per_face as f64 / m.link_bandwidth
}

/// Average torus hop count between communicating neighbours under a
/// rank-to-node mapping quality factor (1.0 = perfect serpentine mapping,
/// the paper's mpi-ext optimization; larger = scattered ranks).
pub fn mapped_hops(t: &Torus, mapping_quality: f64) -> f64 {
    // perfect mapping: neighbours are 1 hop; scattered: average distance
    let avg_dim = (t.dims[0] + t.dims[1] + t.dims[2]) as f64 / 3.0;
    1.0 + (mapping_quality - 1.0) * (avg_dim / 4.0)
}

/// Analytic twin of the rank-resident `--kspace dist --proc` protocol's
/// per-solve coordinator↔worker payload bytes (the quantities
/// [`ProcTraffic`](crate::distpppm::process::ProcTraffic) measures):
/// site slabs in, energy-control round, ghost-halo exchange and force
/// slabs back — everything **except** the ring relay, which the real
/// torus network carries rank-to-rank.  Mirrors the wire layout exactly
/// (36 B/site row + 12 B/rank header, 8 B control scalars, 24 B/ghost
/// point each way, 28 B/rank force header + 24 B/force row); the only
/// modelled quantity is the expected site→brick touch multiplicity,
/// which depends on where the sites actually sit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ResidentTraffic {
    /// `Sites` bytes per solve (expected value — see [`proc_resident_traffic`]).
    pub sites: f64,
    /// `EMax` + `EQuant` bytes per solve.
    pub control: f64,
    /// `Halo` + `HaloSet` bytes per solve (exact ghost-point count).
    pub halo: f64,
    /// `Forces` bytes per solve.
    pub forces: f64,
}

impl ResidentTraffic {
    /// Total per-solve coordinator↔worker bytes.
    pub fn per_solve(&self) -> f64 {
        self.sites + self.control + self.halo + self.forces
    }
}

/// Build the [`ResidentTraffic`] twin for `nsites` charged sites on the
/// given mesh `grid` / rank torus / spline `order`.  Ghost counts come
/// from the same slab partition + low-side halo windows the executed
/// decomposition uses ([`crate::pool::halo_windows`] over
/// [`crate::pool::even_shards`]), so the halo term is exact; the site
/// term uses the expected stencil touch multiplicity
/// `prod_d (1 + r_d (p - 1) / n_d)` (a p-point stencil crosses a slab
/// boundary when its base lies within `p - 1` cells below one).
pub fn proc_resident_traffic(
    grid: [usize; 3],
    ranks: [usize; 3],
    order: usize,
    nsites: usize,
) -> ResidentTraffic {
    use crate::pool::{even_shards, halo_windows};
    let nranks = (ranks[0] * ranks[1] * ranks[2]) as f64;
    let mut touch = 1.0f64;
    for d in 0..3 {
        let m = 1.0 + (ranks[d] * (order - 1)) as f64 / grid[d] as f64;
        touch *= m.min(ranks[d] as f64);
    }
    let slabs: Vec<Vec<std::ops::Range<usize>>> = (0..3)
        .map(|d| even_shards(grid[d], ranks[d]))
        .collect();
    let wins: Vec<_> = (0..3)
        .map(|d| halo_windows(&slabs[d], order - 1, grid[d]))
        .collect();
    let mut ghost_total = 0usize;
    for i in 0..ranks[0] {
        for j in 0..ranks[1] {
            for k in 0..ranks[2] {
                let brick =
                    slabs[0][i].len() * slabs[1][j].len() * slabs[2][k].len();
                let window = wins[0][i].len * wins[1][j].len * wins[2][k].len;
                ghost_total += window - brick;
            }
        }
    }
    ResidentTraffic {
        sites: 12.0 * nranks + 36.0 * nsites as f64 * touch,
        control: 16.0 * nranks,
        halo: 2.0 * 24.0 * ghost_total as f64,
        forces: 28.0 * nranks + 24.0 * nsites as f64,
    }
}

/// Least-squares alpha-beta fit `t = alpha + beta * bytes` over measured
/// `(payload bytes, seconds)` samples — the inverse of [`p2p_time`]'s
/// model, used by the fig8 bench to sit measured per-message timings from
/// the process-executed ring
/// ([`ProcPppm::message_samples`](crate::distpppm::process::ProcPppm::message_samples))
/// next to the analytic collectives above.  Returns `(alpha, beta)`, or
/// `None` when the fit is underdetermined (fewer than two samples, or all
/// samples the same size).
pub fn fit_alpha_beta(samples: &[(usize, f64)]) -> Option<(f64, f64)> {
    if samples.len() < 2 {
        return None;
    }
    let n = samples.len() as f64;
    let (mut sx, mut sy, mut sxx, mut sxy) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    for &(bytes, t) in samples {
        let x = bytes as f64;
        sx += x;
        sy += t;
        sxx += x * x;
        sxy += x * t;
    }
    let det = n * sxx - sx * sx;
    if det.abs() < 1e-12 * n * sxx.max(1.0) {
        return None; // all sizes (numerically) identical: slope unresolvable
    }
    let beta = (n * sxy - sx * sy) / det;
    let alpha = (sy - beta * sx) / n;
    Some((alpha, beta))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mc() -> MachineConfig {
        MachineConfig::default()
    }

    #[test]
    fn p2p_latency_dominates_small_messages() {
        let m = mc();
        let t_small = p2p_time(64, 1, &m);
        let t_big = p2p_time(64 << 20, 1, &m);
        assert!(t_small < 2e-6);
        assert!(t_big > 5e-3); // 64 MB over 6.8 GB/s ~ 9.8 ms
    }

    #[test]
    fn collectives_scale_in_p() {
        let m = mc();
        assert_eq!(allgather_time(1, 100, &m), 0.0);
        let a = allgather_time(8, 1024, &m);
        let b = allgather_time(64, 1024, &m);
        assert!(b > 7.0 * a, "{a} vs {b}");
        let r8 = allreduce_time(8, 1024, &m);
        let r64 = allreduce_time(64, 1024, &m);
        assert!(r64 > r8 && r64 < 3.0 * r8);
    }

    #[test]
    fn alltoall_grows_linearly() {
        let m = mc();
        let t16 = alltoall_time(16, 4096, &m);
        let t32 = alltoall_time(32, 4096, &m);
        assert!((t32 / t16 - 31.0 / 15.0).abs() < 0.01);
    }

    #[test]
    fn perfect_mapping_is_one_hop() {
        let t = Torus::new([8, 12, 8]);
        assert!((mapped_hops(&t, 1.0) - 1.0).abs() < 1e-12);
        assert!(mapped_hops(&t, 2.0) > 2.0);
    }

    #[test]
    fn resident_twin_is_exact_on_the_undivided_torus() {
        // one rank: every site touches exactly one brick, no ghosts
        let t = proc_resident_traffic([12, 18, 12], [1, 1, 1], 5, 100);
        assert_eq!(t.sites, 12.0 + 36.0 * 100.0);
        assert_eq!(t.halo, 0.0);
        assert_eq!(t.control, 16.0);
        assert_eq!(t.forces, 28.0 + 24.0 * 100.0);
    }

    #[test]
    fn resident_twin_halo_counts_low_side_ghost_shells() {
        // grid [8,8,8], ranks [2,1,1], order 5 => halo 4: each brick is
        // 4x8x8 with an 8x8x8 window => 256 ghosts/rank, 512 total, and
        // the exchange pays 24 bytes per point each way
        let t = proc_resident_traffic([8, 8, 8], [2, 1, 1], 5, 10);
        assert_eq!(t.halo, 2.0 * 24.0 * 512.0);
        // per-solve traffic stays far below the 4-transform full-mesh
        // scatter/gather a non-resident protocol would pay
        let full_mesh = (4 * 2 * 16 * 8 * 8 * 8) as f64;
        assert!(t.per_solve() < full_mesh / 2.0, "{}", t.per_solve());
    }

    #[test]
    fn alpha_beta_fit_recovers_a_synthetic_line() {
        let (alpha, beta) = (3.5e-6, 1.0 / 6.8e9);
        let samples: Vec<(usize, f64)> = [64usize, 1024, 65536, 1 << 20]
            .iter()
            .map(|&b| (b, alpha + beta * b as f64))
            .collect();
        let (a, b) = fit_alpha_beta(&samples).expect("well-posed fit");
        assert!((a - alpha).abs() < 1e-9, "alpha {a} vs {alpha}");
        assert!((b / beta - 1.0).abs() < 1e-6, "beta {b} vs {beta}");
    }

    #[test]
    fn alpha_beta_fit_rejects_underdetermined_input() {
        assert!(fit_alpha_beta(&[]).is_none());
        assert!(fit_alpha_beta(&[(1024, 1e-5)]).is_none());
        // many samples, all the same size: slope unresolvable
        let same = vec![(4096usize, 2e-5); 8];
        assert!(fit_alpha_beta(&same).is_none());
    }
}
