//! Minimal discrete-event calendar with counted resources.
//!
//! The large-scale experiments are mostly phase-algebra (max/sum over rank
//! timelines), but utofu-FFT chain scheduling needs real contention: rings
//! queue on a bounded pool of BG chain slots.  This module provides exactly
//! that: jobs with durations, FIFO resource pools, and a virtual clock.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

#[derive(Debug, Clone, Copy, PartialEq)]
struct Event {
    time: f64,
    id: u64,
}

impl Eq for Event {}

impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap by time (then id for determinism)
        other
            .time
            .partial_cmp(&self.time)
            .unwrap()
            .then(other.id.cmp(&self.id))
    }
}

impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Schedule `jobs` (durations in seconds) onto `slots` identical servers,
/// FIFO, work-conserving; returns the makespan.  This is the contention
/// model for BG chain slots and for per-core task queues.
pub fn makespan_fifo(jobs: &[f64], slots: usize) -> f64 {
    assert!(slots >= 1);
    if jobs.is_empty() {
        return 0.0;
    }
    let mut heap: BinaryHeap<Event> = (0..slots.min(jobs.len()))
        .map(|i| Event { time: 0.0, id: i as u64 })
        .collect();
    let mut makespan = 0.0f64;
    for (k, &d) in jobs.iter().enumerate() {
        let slot = heap.pop().unwrap();
        let end = slot.time + d;
        makespan = makespan.max(end);
        heap.push(Event {
            time: end,
            id: slot.id.max(k as u64),
        });
    }
    makespan
}

/// Series of dependent phases, each a parallel bag of per-worker times:
/// total = sum over phases of max over workers (bulk-synchronous model).
pub fn bsp_total(phases: &[Vec<f64>]) -> f64 {
    phases
        .iter()
        .map(|p| p.iter().cloned().fold(0.0, f64::max))
        .sum()
}

/// Overlap of two independent timelines with a final join (the section 3.2
/// pattern): total = max(a, b).
pub fn overlap2(a: f64, b: f64) -> f64 {
    a.max(b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_slot_is_sum() {
        let jobs = [1.0, 2.0, 3.0];
        assert!((makespan_fifo(&jobs, 1) - 6.0).abs() < 1e-12);
    }

    #[test]
    fn infinite_slots_is_max() {
        let jobs = [1.0, 2.0, 3.0];
        assert!((makespan_fifo(&jobs, 100) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn two_slots_balances() {
        let jobs = [3.0, 1.0, 1.0, 1.0];
        assert!((makespan_fifo(&jobs, 2) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn makespan_monotone_in_slots() {
        let jobs: Vec<f64> = (0..20).map(|i| 0.1 + 0.05 * i as f64).collect();
        let mut prev = f64::INFINITY;
        for s in 1..8 {
            let m = makespan_fifo(&jobs, s);
            assert!(m <= prev + 1e-12, "slots {s}");
            prev = m;
        }
    }

    #[test]
    fn bsp_sums_phase_maxima() {
        let t = bsp_total(&[vec![1.0, 2.0], vec![0.5, 0.25]]);
        assert!((t - 2.5).abs() < 1e-12);
    }
}
