//! Host calibration: measure real per-atom costs of both inference paths
//! and the precision ratios that parameterize the DES cost table
//! (DESIGN.md section 7).

use crate::native::NativeModel;
use crate::neighbor::{build_exact, NlistParams};
use crate::md::water::water_box;
use crate::perfmodel::CostTable;
use crate::runtime::manifest::artifacts_dir;
use crate::runtime::{Dtype, PjrtEngine};
use crate::util::json::Json;
use crate::util::stats::{summarize, time_reps};
use anyhow::Result;

#[derive(Debug, Clone)]
/// Host-measured per-atom inference costs feeding the DES cost table.
pub struct Calibration {
    /// native framework-free path, per atom [s]
    pub native_dp_per_atom: f64,
    /// native DW forward, per molecule [s]
    pub native_dw_fwd_per_mol: f64,
    /// native DW VJP, per molecule [s]
    pub native_dw_vjp_per_mol: f64,
    /// XLA/PJRT path (the "framework" baseline), per atom [s]
    pub pjrt_dp_per_atom_f64: f64,
    /// XLA/PJRT path at f32, per atom [s]
    pub pjrt_dp_per_atom_f32: f64,
    /// ratios feeding the cost table
    pub framework_factor: f64,
    /// f64/f32 inference speedup ratio
    pub fp32_speedup: f64,
    /// false when the PJRT numbers are the paper-band fallback (the PJRT
    /// path was unavailable), not host measurements
    pub pjrt_measured: bool,
}

/// Measure host inference costs (`dplr calibrate`), `reps` repetitions.
pub fn run(reps: usize) -> Result<Calibration> {
    let dir = artifacts_dir();
    let nmol = 188; // the 564-atom headline box
    let sys = water_box(nmol, 99);
    let natoms = sys.natoms();
    let coords = sys.coords_flat();
    let p = NlistParams::default();
    let centres: Vec<usize> = (0..natoms).collect();
    let nlist = build_exact(&sys, &centres, &p).data;
    let o_centres: Vec<usize> = (0..nmol).collect();
    let nlist_o = build_exact(&sys, &o_centres, &p).data;
    let box_len = sys.box_len;

    let native = NativeModel::load(&dir)?;
    let t_dp = summarize(&time_reps(2, reps, || {
        let _ = native.dp_ef(&coords, box_len, &nlist);
    }))
    .p50;
    let t_dwf = summarize(&time_reps(2, reps, || {
        let _ = native.dw_fwd(&coords, box_len, &nlist_o);
    }))
    .p50;
    let fwc = vec![0.1; nmol * 3];
    let t_dwv = summarize(&time_reps(2, reps, || {
        let _ = native.dw_vjp(&coords, box_len, &nlist_o, &fwc);
    }))
    .p50;

    let (t_pj64, t_pj32, pjrt_measured) = match PjrtEngine::open(&dir) {
        Ok(mut pjrt) => {
            pjrt.ensure("dp_ef", natoms, Dtype::F64)?;
            let t64 = summarize(&time_reps(2, reps, || {
                let _ = pjrt.dp_ef(&coords, box_len, &nlist, Dtype::F64).unwrap();
            }))
            .p50;
            pjrt.ensure("dp_ef", natoms, Dtype::F32)?;
            let t32 = summarize(&time_reps(2, reps, || {
                let _ = pjrt.dp_ef(&coords, box_len, &nlist, Dtype::F32).unwrap();
            }))
            .p50;
            (t64, t32, true)
        }
        Err(e) => {
            // PJRT path unavailable (stub build / missing artifacts):
            // fall back to the paper's measured framework bands so the
            // cost table stays populated — flagged via pjrt_measured
            eprintln!("calibrate: pjrt path unavailable ({e:#}); using paper-band ratios");
            (t_dp * 8.5, t_dp * 8.5 / 1.45, false)
        }
    };

    Ok(Calibration {
        native_dp_per_atom: t_dp / natoms as f64,
        native_dw_fwd_per_mol: t_dwf / nmol as f64,
        native_dw_vjp_per_mol: t_dwv / nmol as f64,
        pjrt_dp_per_atom_f64: t_pj64 / natoms as f64,
        pjrt_dp_per_atom_f32: t_pj32 / natoms as f64,
        framework_factor: t_pj64 / t_dp,
        fp32_speedup: t_pj64 / t_pj32,
        pjrt_measured,
    })
}

impl Calibration {
    /// Cost table for the DES: host *ratios* + the A64FX anchor
    /// (DESIGN.md section 7 — one anchor, everything else follows).
    pub fn to_cost_table(&self) -> CostTable {
        let mut c = CostTable::default();
        c.framework_factor = self.framework_factor.max(1.0);
        c.fp32_speedup = self.fp32_speedup.max(1.0);
        // keep per-atom *proportions* between DP and DW from the host
        let dw_f = self.native_dw_fwd_per_mol / self.native_dp_per_atom.max(1e-12);
        let dw_b = self.native_dw_vjp_per_mol / self.native_dp_per_atom.max(1e-12);
        c.dw_fwd_per_mol = c.dp_per_atom * dw_f;
        c.dw_bwd_per_mol = c.dp_per_atom * dw_b;
        c
    }

    /// Write the calibration to a JSON file.
    pub fn save(&self, path: &str) -> Result<()> {
        let j = Json::obj(vec![
            ("native_dp_per_atom", Json::Num(self.native_dp_per_atom)),
            ("native_dw_fwd_per_mol", Json::Num(self.native_dw_fwd_per_mol)),
            ("native_dw_vjp_per_mol", Json::Num(self.native_dw_vjp_per_mol)),
            ("pjrt_dp_per_atom_f64", Json::Num(self.pjrt_dp_per_atom_f64)),
            ("pjrt_dp_per_atom_f32", Json::Num(self.pjrt_dp_per_atom_f32)),
            ("framework_factor", Json::Num(self.framework_factor)),
            ("fp32_speedup", Json::Num(self.fp32_speedup)),
            ("pjrt_measured", Json::Bool(self.pjrt_measured)),
        ]);
        std::fs::write(path, j.to_string_pretty())?;
        Ok(())
    }

    /// Print a human-readable summary.
    pub fn print(&self) {
        println!("\n=== Host calibration (564-atom water box) ===");
        if !self.pjrt_measured {
            println!("(pjrt rows are PAPER-BAND ESTIMATES — the PJRT path was unavailable)");
        }
        println!("native  dp_ef      : {:.3} us/atom", self.native_dp_per_atom * 1e6);
        println!("native  dw_fwd     : {:.3} us/mol", self.native_dw_fwd_per_mol * 1e6);
        println!("native  dw_vjp     : {:.3} us/mol", self.native_dw_vjp_per_mol * 1e6);
        println!("pjrt    dp_ef f64  : {:.3} us/atom", self.pjrt_dp_per_atom_f64 * 1e6);
        println!("pjrt    dp_ef f32  : {:.3} us/atom", self.pjrt_dp_per_atom_f32 * 1e6);
        println!(
            "framework factor (pjrt/native): {:.2}x   (paper TF/framework-free: 7.5-9.9x)",
            self.framework_factor
        );
        println!(
            "fp32 speedup (pjrt f64/f32)  : {:.2}x   (paper: 1.3-1.5x)",
            self.fp32_speedup
        );
    }
}
