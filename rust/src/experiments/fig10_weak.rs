//! Fig. 10: weak scaling, 12 -> 8400 nodes at 47 atoms/node, all
//! optimizations on; reports ns/day (paper: 51 at 12 nodes, 32.5 at 8400).

use crate::config::{weak_scaling_configs, MachineConfig};
use crate::md::water::replicated_base_box;
use crate::perfmodel::{ns_per_day, step_time, CostTable, StageFlags};
use crate::tofu::Torus;
use crate::util::table::Table;

#[derive(Debug, Clone)]
/// One weak-scaling data point (Fig. 10).
pub struct Point {
    /// Node count.
    pub nodes: usize,
    /// Total atom count (47/node).
    pub atoms: usize,
    /// Modelled step time [ms].
    pub step_ms: f64,
    /// Resulting throughput [ns/day].
    pub ns_day: f64,
}

fn all_on() -> StageFlags {
    let mut f = StageFlags::default();
    f.native_inference = true;
    f.fp32 = true;
    f.utofu_fft = true;
    f.node_division = true;
    f.ring_lb = true;
    f.overlap = true;
    f
}

/// Torus dims used for each weak-scaling node count (factored near-cubes).
fn torus_for(nodes: usize) -> [usize; 3] {
    match nodes {
        12 => [2, 3, 2],
        96 => [4, 6, 4],
        324 => [6, 9, 6],
        768 => [8, 12, 8],
        2160 => [12, 15, 12],
        4608 => [16, 18, 16],
        8400 => [20, 21, 20],
        n => {
            let c = (n as f64).cbrt().round() as usize;
            [c.max(1), c.max(1), c.max(1)]
        }
    }
}

/// Model every weak-scaling configuration of section 4.4.
pub fn run(cost: &CostTable, machine: &MachineConfig) -> Vec<Point> {
    let flags = all_on();
    weak_scaling_configs()
        .into_iter()
        .map(|(nodes, rep)| {
            let sys = replicated_base_box(rep, 1);
            let torus = Torus::new(torus_for(nodes));
            let b = step_time(&sys, &torus, flags, cost, machine);
            Point {
                nodes,
                atoms: sys.natoms(),
                step_ms: b.total() * 1e3,
                ns_day: ns_per_day(b.total()),
            }
        })
        .collect()
}

/// Print the Fig. 10 table.
pub fn print_points(points: &[Point]) {
    println!("\n=== Fig 10: weak scaling, 47 atoms/node, all optimizations ===");
    let mut t = Table::new(&["nodes", "atoms", "ms/step", "ns/day"]);
    for p in points {
        t.row(&[
            p.nodes.to_string(),
            p.atoms.to_string(),
            format!("{:.3}", p.step_ms),
            format!("{:.1}", p.ns_day),
        ]);
    }
    t.print();
    println!(
        "(paper anchors: 51 ns/day at 12 nodes / 564 atoms, 32.5 ns/day at \
         8400 nodes / ~400K atoms)"
    );
}
