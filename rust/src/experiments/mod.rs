//! Experiment harnesses: one module per paper table/figure, shared by the
//! `dplr` CLI and the `cargo bench` targets (DESIGN.md section 6).

pub mod calibrate;
pub mod fig10_weak;
pub mod fig7_longrun;
pub mod fig8_fft;
pub mod fig9_stepopt;
pub mod mts_drift;
pub mod table1_accuracy;
