//! The `--mts k` conserved-quantity drift harness (`dplr mtsdrift`, the
//! CI `mts-drift` gate): short deterministic NVE trajectories at k-space
//! strides `k` on each requested backend, reporting the conserved-energy
//! drift per atom per step against a Table-1-derived threshold.
//!
//! **Threshold derivation.**  Table 1 budgets `1e-4` eV/atom of energy
//! error per k-space evaluation for the production meshes
//! (`rust/tests/kspace_parity.rs` pins the same bound at the engine
//! level).  A trustworthy stride must not leak more than that budget per
//! step into the NVE conserved quantity, so the gate is
//! `|drift| <= 1e-4 eV/(atom*step)`.  Velocity-Verlet fluctuation on an
//! equilibrated box sits orders of magnitude below this bound, while a
//! destabilized stride (e.g. broken held-force bookkeeping) blows
//! exponentially past it — the gate is insensitive to host timing yet
//! trips on any real instability.
//!
//! Deterministic by construction: fixed seeds, fixed dt, f64 end to end,
//! synthetic-weight fallback when the fitted artifacts are absent (the
//! drift of the stride is a property of the dynamics, not of which
//! weights produced them), so CI runs bit-identical trajectories on
//! every host.

use crate::engine::{KspaceConfig, MtsExtrap, ShortRangeModel, Simulation, StepContext};
use crate::md::scenario;
use crate::native::NativeModel;
use crate::runtime::manifest::artifacts_dir;
use crate::util::stats::summarize;
use crate::util::table::Table;
use anyhow::{bail, Result};
use std::sync::{Arc, Mutex};

/// Conserved-quantity drift budget: the Table-1 per-atom energy error
/// budget (1e-4 eV/atom, see the module docs) applied per production
/// step, in eV/(atom*step).
pub const DRIFT_THRESHOLD: f64 = 1.0e-4;

/// Run configuration for the drift harness.
pub struct Config {
    /// Water molecules in the box.
    pub nmol: usize,
    /// Scenario spec (`md::scenario`): the gate runs the same NVE drift
    /// contract on ionic and slab boxes (`dplr mtsdrift --system nacl`).
    pub system: String,
    /// Production (measured) NVE steps.
    pub steps: usize,
    /// Quench steps before production.
    pub quench: usize,
    /// MD timestep [fs].
    pub dt_fs: f64,
    /// K-space strides to gate.
    pub ks: Vec<usize>,
    /// Backends to gate (`pppm` | `ewald` | `dist`).
    pub backends: Vec<String>,
    /// Between-solve carry strategy.
    pub extrap: MtsExtrap,
    /// Worker-pool size (None = `DPLR_THREADS` or 1).
    pub threads: Option<usize>,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            nmol: 32,
            system: "water".to_string(),
            steps: 200,
            quench: 80,
            dt_fs: 0.5,
            ks: vec![1, 2, 4],
            backends: vec!["pppm".to_string(), "dist".to_string()],
            extrap: MtsExtrap::Hold,
            threads: None,
        }
    }
}

/// One gate row: the measured drift of a (backend, k) combination.
#[derive(Debug, Clone)]
pub struct Row {
    /// K-space backend label.
    pub backend: String,
    /// K-space solve stride.
    pub k: usize,
    /// Between-solve carry strategy.
    pub extrap: MtsExtrap,
    /// |second-half mean - first-half mean| of the conserved quantity,
    /// per half-trace step, per atom [eV/(atom*step)].
    pub drift: f64,
    /// The gate threshold the row was judged against.
    pub threshold: f64,
    /// `drift <= threshold`.
    pub pass: bool,
    /// Second-half standard deviation of the conserved quantity [eV].
    pub conserved_sd: f64,
}

fn backend_config(name: &str) -> Result<KspaceConfig> {
    Ok(match name {
        "pppm" => KspaceConfig::PppmAuto { alpha: 0.3 },
        "ewald" => KspaceConfig::Ewald {
            alpha: 0.3,
            tol: 1e-10,
        },
        // a real 2x2x1 torus so the gate exercises brick decomposition +
        // ghost halos, not the ranks-1 bit-identity fast path
        "dist" => KspaceConfig::Dist {
            alpha: 0.3,
            ranks: [2, 2, 1],
            quantized: false,
            matvec: false,
        },
        other => bail!("unknown mts-drift backend {other} (expected pppm|ewald|dist)"),
    })
}

fn load_or_synthetic() -> Box<dyn ShortRangeModel> {
    match NativeModel::load(&artifacts_dir()) {
        Ok(m) => Box::new(m),
        Err(_) => Box::new(NativeModel::synthetic(20250710)),
    }
}

fn run_one(cfg: &Config, backend: &str, k: usize) -> Result<Row> {
    let sys = scenario::build(&cfg.system, cfg.nmol, 2026)?;
    let trace: Arc<Mutex<Vec<f64>>> = Arc::new(Mutex::new(Vec::with_capacity(cfg.steps)));
    let sink = trace.clone();
    let mut builder = Simulation::builder(sys)
        .dt_fs(cfg.dt_fs)
        .nve()
        .temperature(300.0)
        .mts(k)
        .mts_extrap(cfg.extrap)
        .kspace(backend_config(backend)?)
        .short_range(load_or_synthetic())
        .observe(move |ctx: &StepContext| {
            sink.lock().unwrap().push(ctx.obs.conserved);
        });
    if let Some(t) = cfg.threads {
        builder = builder.threads(t);
    }
    let mut sim = builder.build()?;
    sim.quench(cfg.quench)?;
    sim.reheat(300.0, 29);
    sim.run(cfg.steps)?;

    // drift estimator: difference of the two half-trace means per
    // half-trace step (the `dplr replicas` stability readout), per atom
    let natoms = sim.sys.natoms() as f64;
    let trace = trace.lock().unwrap();
    let half = trace.len() / 2;
    let (drift, sd) = if half > 0 {
        let (a, b) = trace.split_at(half);
        let (sa, sb) = (summarize(a), summarize(b));
        (((sb.mean - sa.mean) / half as f64 / natoms).abs(), sb.std)
    } else {
        (0.0, 0.0)
    };
    Ok(Row {
        backend: backend.to_string(),
        k,
        extrap: cfg.extrap,
        drift,
        threshold: DRIFT_THRESHOLD,
        pass: drift <= DRIFT_THRESHOLD,
        conserved_sd: sd,
    })
}

/// Run the drift harness over every (backend, k) combination.
pub fn run(cfg: &Config) -> Result<Vec<Row>> {
    let mut rows = Vec::with_capacity(cfg.backends.len() * cfg.ks.len());
    for backend in &cfg.backends {
        for &k in &cfg.ks {
            rows.push(run_one(cfg, backend, k)?);
        }
    }
    Ok(rows)
}

/// Print the gate table.
pub fn print_rows(rows: &[Row]) {
    let mut t = Table::new(&[
        "backend",
        "k",
        "extrap",
        "drift [eV/(atom*step)]",
        "threshold",
        "cons. sd [eV]",
        "verdict",
    ]);
    for r in rows {
        t.row(&[
            r.backend.clone(),
            r.k.to_string(),
            r.extrap.name().to_string(),
            format!("{:.3e}", r.drift),
            format!("{:.1e}", r.threshold),
            format!("{:.2e}", r.conserved_sd),
            if r.pass { "pass".to_string() } else { "FAIL".to_string() },
        ]);
    }
    println!("\n=== MTS conserved-quantity drift gate ===");
    t.print();
}
