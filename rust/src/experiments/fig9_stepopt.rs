//! Fig. 9: step-by-step optimization ladder at 96 and 768 nodes,
//! 47 atoms/node, 100 time-steps, with the kspace/comm/dw_fwd/
//! dw_bwd+dp_all/others breakdown and cumulative speedups.

use crate::config::MachineConfig;
use crate::md::water::replicated_base_box;
use crate::perfmodel::{step_time, Breakdown, CostTable, StageFlags};
use crate::tofu::Torus;
use crate::util::table::Table;

#[derive(Debug, Clone)]
/// One rung of the Fig. 9 optimization ladder.
pub struct Stage {
    /// Stage label ("+FP32", ...).
    pub name: &'static str,
    /// Modelled per-step time breakdown.
    pub breakdown: Breakdown,
    /// Cumulative speedup over the unoptimized baseline.
    pub speedup_vs_baseline: f64,
}

/// Model the full ladder for one topology.
pub fn run(
    node_dims: [usize; 3],
    replication: [usize; 3],
    cost: &CostTable,
    machine: &MachineConfig,
) -> Vec<Stage> {
    let sys = replicated_base_box(replication, 1);
    let torus = Torus::new(node_dims);
    let ladder = StageFlags::ladder();
    let base = step_time(&sys, &torus, ladder[0].1, cost, machine).total();
    ladder
        .into_iter()
        .map(|(name, flags)| {
            let breakdown = step_time(&sys, &torus, flags, cost, machine);
            Stage {
                name,
                speedup_vs_baseline: base / breakdown.total(),
                breakdown,
            }
        })
        .collect()
}

/// Print the ladder table for one node count.
pub fn print_stages(nodes: usize, stages: &[Stage]) {
    println!("\n=== Fig 9: step-by-step optimization, {nodes} nodes (100 steps) ===");
    let mut t = Table::new(&[
        "stage",
        "kspace [s]",
        "comm [s]",
        "dw_fwd [s]",
        "dw_bwd+dp_all [s]",
        "others [s]",
        "total/100 steps",
        "speedup",
    ]);
    for s in stages {
        let b = &s.breakdown;
        t.row(&[
            s.name.to_string(),
            format!("{:.3}", 100.0 * b.kspace),
            format!("{:.3}", 100.0 * b.comm),
            format!("{:.3}", 100.0 * b.dw_fwd),
            format!("{:.3}", 100.0 * b.dp_dw_bwd),
            format!("{:.3}", 100.0 * b.others),
            format!("{:.3}", 100.0 * b.total()),
            format!("{:.1}x", s.speedup_vs_baseline),
        ]);
    }
    t.print();
}

/// Paper configurations: 96 nodes = (4,6,4) topo + (2,2,2) replication;
/// 768 nodes = (8,12,8) + (4,4,4).
pub fn paper_configs() -> Vec<(usize, [usize; 3], [usize; 3])> {
    vec![(96, [4, 6, 4], [2, 2, 2]), (768, [8, 12, 8], [4, 4, 4])]
}
