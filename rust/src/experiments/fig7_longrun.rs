//! Fig. 7: long NVT trajectories under Double vs Mixed-int2 precision —
//! energy and temperature traces must coincide and stay stable.
//!
//! Paper: 50k steps on the 128-water system.  Defaults here are scaled to
//! one CPU (the trace density, not the physics, is what the figure shows);
//! `--steps` restores any length.  Trace sampling rides the engine's
//! observer hook instead of a hand-rolled run loop.

use crate::engine::{KspaceConfig, MtsExtrap, Simulation, StepContext};
use crate::md::water::water_box;
use crate::native::NativeModel;
use crate::pppm::{MeshMode, PppmConfig};
use crate::runtime::manifest::artifacts_dir;
use crate::util::json::Json;
use crate::util::rng::Rng;
use anyhow::Result;
use std::sync::{Arc, Mutex};

/// Run configuration for the Fig. 7 traces.
pub struct Config {
    /// Water molecules in the box.
    pub nmol: usize,
    /// Production steps per trace.
    pub steps: usize,
    /// Observable sampling stride.
    pub sample_every: usize,
    /// Optional JSON output path for the traces.
    pub out_json: Option<String>,
    /// K-space strides for the MTS section (`run_mts`).
    pub mts_ks: Vec<usize>,
    /// Between-solve carry strategy for the MTS section.
    pub mts_extrap: MtsExtrap,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            nmol: 128,
            steps: 1500,
            sample_every: 10,
            out_json: Some("fig7_traces.json".to_string()),
            mts_ks: vec![2, 4],
            mts_extrap: MtsExtrap::Linear,
        }
    }
}

#[derive(Debug, Clone, Default)]
/// Sampled observables of one NVT run.
pub struct Trace {
    /// Precision-configuration label.
    pub label: String,
    /// Sampled step indices.
    pub step: Vec<u64>,
    /// Conserved quantity per sample [eV].
    pub energy: Vec<f64>,
    /// Temperature per sample [K].
    pub temperature: Vec<f64>,
}

fn run_one(
    cfg: &Config,
    label: &str,
    mode: Option<MeshMode>,
    mts: (usize, MtsExtrap),
) -> Result<Trace> {
    let mut sys = water_box(cfg.nmol, 4242);
    let mut rng = Rng::new(17);
    sys.thermalize(300.0, &mut rng);
    let alpha = 0.3;
    let kspace = match mode {
        None => KspaceConfig::PppmAuto { alpha },
        Some(mode) => {
            let mut mesh = PppmConfig::new([8, 12, 8], 5, alpha);
            mesh.mode = mode;
            KspaceConfig::Pppm(mesh)
        }
    };
    // trace sampling as an observer: `step` counts production steps only
    // (quench is suppressed), shared with this caller through an Arc
    let trace = Arc::new(Mutex::new(Trace {
        label: label.to_string(),
        ..Trace::default()
    }));
    let sink = trace.clone();
    let sample_every = cfg.sample_every.max(1) as u64;
    let mut sim = Simulation::builder(sys)
        .thermostat(300.0, 0.5)
        .overlap(true)
        .mts(mts.0)
        .mts_extrap(mts.1)
        .kspace(kspace)
        .short_range(Box::new(NativeModel::load(&artifacts_dir())?))
        .observe(move |ctx: &StepContext| {
            // 0-based production index, matching the pre-observer traces
            let s = ctx.step - 1;
            if s % sample_every == 0 {
                let o = ctx.obs;
                let mut tr = sink.lock().unwrap();
                tr.step.push(s);
                tr.energy.push(o.e_sr + o.e_gt + o.kinetic);
                tr.temperature.push(o.temperature);
            }
        })
        .build()?;
    // longer relaxation than the quick examples: Fig 7 measures
    // equilibrium stability, so shed the lattice-packing energy first
    sim.quench(120)?;
    sim.reheat(300.0, 23);
    sim.run(cfg.steps)?;
    let tr = trace.lock().unwrap().clone();
    Ok(tr)
}

/// Run the double and mixed-int NVT traces (`dplr longrun`).
pub fn run(cfg: &Config) -> Result<(Trace, Trace)> {
    let unstrided = (1, MtsExtrap::Hold);
    let double = run_one(cfg, "double", None, unstrided)?;
    let quant = run_one(
        cfg,
        "mixed-int2",
        Some(MeshMode::QuantInt32 { nseg: [2, 3, 2] }),
        unstrided,
    )?;
    if let Some(path) = &cfg.out_json {
        let dump = |t: &Trace| {
            Json::obj(vec![
                ("label", Json::Str(t.label.clone())),
                (
                    "step",
                    Json::Arr(t.step.iter().map(|&s| Json::Num(s as f64)).collect()),
                ),
                ("energy", Json::arr_f64(&t.energy)),
                ("temperature", Json::arr_f64(&t.temperature)),
            ])
        };
        let j = Json::Arr(vec![dump(&double), dump(&quant)]);
        std::fs::write(path, j.to_string_pretty())?;
    }
    Ok((double, quant))
}

/// Run the `--mts` section: strided double-precision traces, one per
/// stride in `cfg.mts_ks` (plus the physics of the k=1 trace already
/// produced by [`run`]).  Same box, seeds, thermostat, and relaxation as
/// the main traces, so the strided energies are directly comparable to
/// the `double` trace.
pub fn run_mts(cfg: &Config) -> Result<Vec<Trace>> {
    let mut traces = Vec::with_capacity(cfg.mts_ks.len());
    for &k in &cfg.mts_ks {
        let label = format!("double-mts{k}-{}", cfg.mts_extrap.name());
        traces.push(run_one(cfg, &label, None, (k, cfg.mts_extrap))?);
    }
    Ok(traces)
}

/// Print stability statistics of the strided traces from [`run_mts`].
pub fn print_mts_summary(traces: &[Trace]) {
    if traces.is_empty() {
        return;
    }
    let stat = |v: &[f64]| {
        let n = v.len().max(1) as f64;
        let mean = v.iter().sum::<f64>() / n;
        let sd = (v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n).sqrt();
        (mean, sd)
    };
    println!("\n=== Fig 7 (MTS): strided k-space traces ===");
    for t in traces {
        let half = t.energy.len() / 2;
        let (ea, _) = stat(&t.energy[..half.max(1)]);
        let (em, es) = stat(&t.energy[half..]);
        let (tm, ts) = stat(&t.temperature[half..]);
        // per-sample drift between the half-trace means: the long-run
        // analogue of the `dplr mtsdrift` gate readout
        let drift = (em - ea).abs() / (half.max(1) as f64);
        println!(
            "{:>20}: <E> = {:.3} +- {:.3} eV   <T> = {:.1} +- {:.1} K   \
             half-mean drift = {:.2e} eV/sample   ({} samples)",
            t.label,
            em,
            es,
            tm,
            ts,
            drift,
            t.energy.len()
        );
    }
}

/// Print drift/temperature statistics of the two traces.
pub fn print_summary(a: &Trace, b: &Trace) {
    let stat = |v: &[f64]| {
        let n = v.len().max(1) as f64;
        let mean = v.iter().sum::<f64>() / n;
        let sd = (v.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n).sqrt();
        (mean, sd)
    };
    println!("\n=== Fig 7: long NVT run, double vs mixed-int2 ===");
    for t in [a, b] {
        let half = t.energy.len() / 2;
        let (em, es) = stat(&t.energy[half..]);
        let (tm, ts) = stat(&t.temperature[half..]);
        println!(
            "{:>12}: <E> = {:.3} +- {:.3} eV   <T> = {:.1} +- {:.1} K   ({} samples)",
            t.label,
            em,
            es,
            tm,
            ts,
            t.energy.len()
        );
    }
    let half = a.energy.len() / 2;
    let (ea, _) = stat(&a.energy[half..]);
    let (eb, _) = stat(&b.energy[half..]);
    println!(
        "trace separation: |<E>_double - <E>_int2| = {:.4} eV ({:.2e} rel)",
        (ea - eb).abs(),
        (ea - eb).abs() / ea.abs().max(1.0)
    );
}
