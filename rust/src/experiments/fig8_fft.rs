//! Fig. 8: distributed 3-D FFT comparison (FFT-MPI/all, heFFTe/all,
//! heFFTe/master, utofu-FFT/master) across per-node grids 4^3/5^3/6^3 and
//! the paper's node counts; 1000 iterations of brick2fft + poisson_ik.

use crate::config::{paper_topologies, MachineConfig};
use crate::distfft::{fftmpi_time, heffte_time, utofu_time, Participation};
use crate::tofu::{BgPayload, Torus};
use crate::util::table::Table;

#[derive(Debug, Clone)]
/// One Fig. 8 row: a (node count, grid/node) configuration.
pub struct Row {
    /// Node count.
    pub nodes: usize,
    /// Grid points per node per dimension (4/5/6).
    pub grid_per_node: usize,
    /// seconds for 1000 iterations, per method (None = unsupported)
    pub fftmpi_all: f64,
    /// heFFTe, all ranks (None = unsupported regime).
    pub heffte_all: Option<f64>,
    /// heFFTe, master ranks only.
    pub heffte_master: Option<f64>,
    /// utofu-FFT (the paper's contribution).
    pub utofu_master: f64,
}

/// Model every Fig. 8 configuration.
pub fn run(machine: &MachineConfig) -> Vec<Row> {
    let mut rows = Vec::new();
    for per_node in [4usize, 5, 6] {
        for (nodes, dims) in paper_topologies() {
            let t = Torus::new(dims);
            let grid = [
                dims[0] * per_node,
                dims[1] * per_node,
                dims[2] * per_node,
            ];
            let iters = 1000.0;
            rows.push(Row {
                nodes,
                grid_per_node: per_node,
                fftmpi_all: iters * fftmpi_time(grid, &t, Participation::All, machine).total(),
                heffte_all: heffte_time(grid, &t, Participation::All, machine)
                    .map(|c| iters * c.total()),
                heffte_master: heffte_time(grid, &t, Participation::Master, machine)
                    .map(|c| iters * c.total()),
                utofu_master: iters
                    * utofu_time(grid, &t, BgPayload::PackedI32, machine).total(),
            });
        }
    }
    rows
}

/// Print the Fig. 8 tables (one per grid/node).
pub fn print_rows(rows: &[Row]) {
    println!("\n=== Fig 8: 1000 x (brick2fft + poisson_ik) [seconds] ===");
    for per_node in [4usize, 5, 6] {
        let mut t = Table::new(&[
            "nodes",
            "FFT-MPI/all",
            "heFFTe/all",
            "heFFTe/master",
            "utofu-FFT/master",
            "utofu speedup",
        ]);
        for r in rows.iter().filter(|r| r.grid_per_node == per_node) {
            let fmt = |x: Option<f64>| match x {
                Some(v) => format!("{v:.3}"),
                None => "n/a".to_string(),
            };
            t.row(&[
                r.nodes.to_string(),
                format!("{:.3}", r.fftmpi_all),
                fmt(r.heffte_all),
                fmt(r.heffte_master),
                format!("{:.3}", r.utofu_master),
                format!("{:.2}x", r.fftmpi_all / r.utofu_master),
            ]);
        }
        println!("--- {per_node}^3 grid points per node ---");
        t.print();
    }
}
