//! Table 1: single-step energy/force error per precision configuration.
//!
//! Paper setup: 128-water box (~16 A), five configurations — Double 32^3
//! (baseline), Mixed-fp32 32^3, Mixed-int at 12x18x12 / 10x15x10 / 8x12x8
//! grids on the 12-node (2x3x2) topology.  The paper's reference is AIMD;
//! our model *is* the potential, so the reference here is the exact
//! direct k-space sum + double-precision NN — the same experimental
//! structure (error of a precision config against the golden answer).
//!
//! Both providers flow through the engine traits: the NN path is a
//! `&dyn ShortRangeModel` (native f64 or the f32 PJRT artifacts) and the
//! k-space path a `&mut dyn KspaceSolver` (the exact `EwaldRecipSolver`
//! for the golden row, `Pppm` for every configuration under test) — the
//! same seams the engine itself dispatches through.
//!
//! `Config::system` reruns the sweep on any `md::scenario` box (NaCl
//! electrolyte, charged slab, mixed solute): charges come from the
//! species table, and slab rows add the Yeh-Berkowitz EW3DC dipole
//! correction to the golden *and* candidate sides.

use crate::engine::{
    KspaceConfig, KspaceSolver, MtsExtrap, PjrtModel, ShortRangeModel, Simulation, StepTimes,
};
use crate::ewald::EwaldRecipSolver;
use crate::md::scenario;
use crate::md::system::System;
use crate::native::NativeModel;
use crate::pppm::MeshMode;
use crate::runtime::manifest::artifacts_dir;
use crate::runtime::Dtype;
use crate::util::rng::Rng;
use crate::util::table::Table;
use anyhow::Result;

#[derive(Debug, Clone)]
/// One Table-1 row: errors of a precision configuration.
pub struct Row {
    /// Configuration label.
    pub name: String,
    /// Mesh used for the row.
    pub grid: [usize; 3],
    /// |dE| per atom vs the exact Ewald reference [eV].
    pub energy_err_per_atom: f64,
    /// Force RMS error [eV/A].
    pub force_rms_err: f64,
    /// Worst single-component force error [eV/A].
    pub force_max_err: f64,
}

/// Run configuration for the Table-1 sweep.
pub struct Config {
    /// Water molecules in the box.
    pub nmol: usize,
    /// Scenario spec (`md::scenario`): the rows measure the same
    /// precision errors on ionic/slab boxes (slab rows add the EW3DC
    /// dipole correction to *both* sides of the comparison).
    pub system: String,
    /// Ring segments per dimension for the quantized rows.
    pub nseg: [usize; 3],
    /// equilibration steps before the measured single step
    pub equil: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            nmol: 128,
            system: "water".to_string(),
            nseg: [2, 3, 2], // the paper's 12-node 2x3x2 topology
            equil: 20,
        }
    }
}

/// Build a mildly-equilibrated 128-water state shared by all rows: the
/// 32^3 double-precision Table-1 baseline through the builder API.
fn reference_state(cfg: &Config) -> Result<Simulation> {
    let mut sys = scenario::build(&cfg.system, cfg.nmol, 2025)?;
    let mut rng = Rng::new(5);
    sys.thermalize(300.0, &mut rng);
    let mesh = crate::pppm::PppmConfig::new([32, 32, 32], 5, 0.3);
    let mut sim = Simulation::builder(sys)
        .kspace(KspaceConfig::Pppm(mesh))
        .short_range(Box::new(NativeModel::load(&artifacts_dir())?))
        .build()?;
    sim.quench(cfg.equil)?;
    sim.rescale_to(300.0);
    Ok(sim)
}

/// Evaluate every precision configuration on one equilibrated frame.
pub fn run(cfg: &Config) -> Result<Vec<Row>> {
    let dir = artifacts_dir();
    let sim = reference_state(cfg)?;
    let sys = sim.sys.clone();
    let coords = sys.coords_flat();
    let nmol = sys.nmol;
    let natoms = sys.natoms();
    let alpha = 0.3;

    // neighbour lists at the measured state
    let p = crate::neighbor::NlistParams::default();
    let centres: Vec<usize> = (0..natoms).collect();
    let nlist = crate::neighbor::build_exact(&sys, &centres, &p).data;
    let o_centres: Vec<usize> = (0..nmol).collect();
    let nlist_o = crate::neighbor::build_exact(&sys, &o_centres, &p).data;

    // ---- golden reference: native f64 NN + exact direct k-space sum
    // (EW3DC-corrected for slab scenarios, on both sides) ----
    let mut native = NativeModel::load(&dir)?;
    native.install_type_map(&sys.types);
    let mut golden_kspace = EwaldRecipSolver::new(alpha, sys.box_len, 1e-14);
    let golden = full_forces(&native, &mut golden_kspace, &sys, &coords, &nlist, &nlist_o)?;

    let mut rows = Vec::new();
    let configs: Vec<(&str, [usize; 3], MeshMode, bool)> = vec![
        ("Double(32x32x32)", [32, 32, 32], MeshMode::Double, false),
        ("Mixed-fp32(32x32x32)", [32, 32, 32], MeshMode::F32, true),
        (
            "Mixed-int0(12x18x12)",
            [12, 18, 12],
            MeshMode::QuantInt32 { nseg: cfg.nseg },
            true,
        ),
        (
            "Mixed-int1(10x15x10)",
            [10, 15, 10],
            MeshMode::QuantInt32 { nseg: cfg.nseg },
            true,
        ),
        (
            "Mixed-int2(8x12x8)",
            [8, 12, 8],
            MeshMode::QuantInt32 { nseg: cfg.nseg },
            true,
        ),
    ];

    for (name, grid, mode, f32_nn) in configs {
        // NN precision: f32 rows use the f32 PJRT artifacts (the paper's
        // "neural network computations reduced to single precision"); when
        // the PJRT path is unavailable (stub build) they fall back to the
        // native f64 NN, leaving only the mesh precision under test
        let pjrt;
        let mut nn_fallback = false;
        // non-water scenarios reject backends without generalized index
        // math at set_type_map, falling into the same f64 fallback
        let nn: &dyn ShortRangeModel = if f32_nn {
            let opened = PjrtModel::open(&dir, Dtype::F32).and_then(|mut m| {
                m.set_type_map(&sys.types)?;
                Ok(m)
            });
            match opened {
                Ok(m) => {
                    pjrt = m;
                    &pjrt
                }
                Err(e) => {
                    eprintln!(
                        "table1: row '{name}' requested the f32 PJRT NN but the PJRT \
                         path is unavailable ({e:#}); computing this row with the \
                         native f64 NN — only the mesh precision differs"
                    );
                    nn_fallback = true;
                    &native
                }
            }
        } else {
            &native
        };
        // carry the substitution in the row label so persisted/printed
        // rows are never mistaken for real f32-NN measurements
        let name = if nn_fallback {
            format!("{name} [NN=f64 fallback]")
        } else {
            name.to_string()
        };
        let mut mesh_cfg = crate::pppm::PppmConfig::new(grid, 5, alpha);
        mesh_cfg.mode = mode;
        let mut pppm = crate::pppm::Pppm::new(mesh_cfg, sys.box_len);
        let got = full_forces(nn, &mut pppm, &sys, &coords, &nlist, &nlist_o)?;
        let de = (got.0 - golden.0).abs() / natoms as f64;
        let mut rms = 0.0;
        let mut maxe = 0.0f64;
        for (a, b) in got.1.iter().zip(&golden.1) {
            let d = (a - b).abs();
            rms += d * d;
            maxe = maxe.max(d);
        }
        rms = (rms / got.1.len() as f64).sqrt();
        rows.push(Row {
            name,
            grid,
            energy_err_per_atom: de,
            force_rms_err: rms,
            force_max_err: maxe,
        });
    }
    Ok(rows)
}

/// One full force evaluation through the engine's provider traits: any
/// `ShortRangeModel` for DP/DW, any `KspaceSolver` for E_Gt.  Site
/// charges come from the system's species table; slab systems get the
/// Yeh-Berkowitz EW3DC dipole correction on top of the solver output —
/// for *every* solver, so golden and candidate rows stay comparable.
fn full_forces(
    nn: &dyn ShortRangeModel,
    kspace: &mut dyn KspaceSolver,
    sys: &System,
    coords: &[f64],
    nlist: &[i32],
    nlist_o: &[i32],
) -> Result<(f64, Vec<f64>)> {
    let natoms = coords.len() / 3;
    let (nmol, box_len) = (sys.nmol, sys.box_len);
    let (e_sr, f_sr) = nn.dp_ef(coords, box_len, nlist)?;
    let delta = nn.dw_fwd(coords, box_len, nlist_o)?;
    let mut sites = Vec::with_capacity(natoms + nmol);
    let mut q = Vec::with_capacity(natoms + nmol);
    for i in 0..natoms {
        sites.push([coords[3 * i], coords[3 * i + 1], coords[3 * i + 2]]);
        q.push(sys.types.charge_of(i));
    }
    let q_wc = sys.types.wc_charge();
    for n in 0..nmol {
        sites.push([
            coords[3 * n] + delta[3 * n],
            coords[3 * n + 1] + delta[3 * n + 1],
            coords[3 * n + 2] + delta[3 * n + 2],
        ]);
        q.push(q_wc);
    }
    let mut f_sites = Vec::new();
    let mut e_gt = kspace.energy_forces_into(&sites, &q, &mut f_sites);
    if sys.slab {
        e_gt += crate::ewald::ew3dc(&sites, &q, box_len, &mut f_sites);
    }
    let mut f_wc = vec![0.0; nmol * 3];
    for n in 0..nmol {
        for d in 0..3 {
            f_wc[3 * n + d] = f_sites[natoms + n][d];
        }
    }
    let (_, fc) = nn.dw_vjp(coords, box_len, nlist_o, &f_wc)?;
    let mut forces = vec![0.0; natoms * 3];
    for i in 0..natoms {
        for d in 0..3 {
            forces[3 * i + d] = f_sr[3 * i + d] + f_sites[i][d] + fc[3 * i + d];
        }
    }
    Ok((e_sr + e_gt, forces))
}

/// Stride-error rows: how far the `--mts k` held/extrapolated reciprocal
/// forces stray from a fresh solve across one stride window.
///
/// Offline by construction: record the charge-site frames of a short
/// *unstrided* trajectory, then replay the engine's exact carry rules
/// (`engine::mts` semantics — hold the solve at step `2k`, or linearly
/// extrapolate from the solves at steps `k` and `2k`) against a fresh
/// 32^3 double-precision solve at each intermediate frame `2k + m`,
/// `m = 1..k`.  Each row reports the worst intermediate step of the
/// window.  Errors are measured on the charge-site forces — the exact
/// quantity the stride holds between solves (the bitwise engine-level
/// behaviour is pinned separately by `rust/tests/mts_invariance.rs`).
/// Falls back to synthetic NN weights when the fitted artifacts are
/// absent: the stride error is a property of the dynamics and the mesh,
/// not of which weights produced the trajectory.
pub fn mts_stride_rows(cfg: &Config, ks: &[usize]) -> Result<Vec<Row>> {
    let model: Box<dyn ShortRangeModel> = match NativeModel::load(&artifacts_dir()) {
        Ok(m) => Box::new(m),
        Err(_) => Box::new(NativeModel::synthetic(20250710)),
    };
    let mut sys = scenario::build(&cfg.system, cfg.nmol, 2025)?;
    let mut rng = Rng::new(5);
    sys.thermalize(300.0, &mut rng);
    let grid = [32, 32, 32];
    let mesh = crate::pppm::PppmConfig::new(grid, 5, 0.3);
    let mut sim = Simulation::builder(sys)
        .dt_fs(0.5)
        .kspace(KspaceConfig::Pppm(mesh))
        .short_range(model)
        .build()?;
    sim.quench(cfg.equil)?;
    sim.rescale_to(300.0);

    // record the charge-site frames of an unstrided trajectory: one
    // in-place evaluation at the equilibrated state, then one per step
    let kmax = ks.iter().copied().max().unwrap_or(0).max(2);
    let nframes = 3 * kmax;
    let mut times = StepTimes::default();
    let mut frames = Vec::with_capacity(nframes);
    sim.evaluate_forces(&mut times)?;
    frames.push((sim.sites.clone(), sim.charges.clone()));
    for _ in 1..nframes {
        sim.step()?;
        frames.push((sim.sites.clone(), sim.charges.clone()));
    }

    // fresh double-precision solve at every frame (one solver reused:
    // the mesh contract is state-free — same sites in, same bits out)
    let gold_cfg = crate::pppm::PppmConfig::new(grid, 5, 0.3);
    let mut gold = crate::pppm::Pppm::new(gold_cfg, sim.sys.box_len);
    let mut golden: Vec<(f64, Vec<[f64; 3]>)> = Vec::with_capacity(frames.len());
    let mut buf = Vec::new();
    for (sites, q) in &frames {
        let mut e = gold.energy_forces_into(sites, q, &mut buf);
        if sim.sys.slab {
            // match the engine: held solves carry the EW3DC correction
            e += crate::ewald::ew3dc(sites, q, sim.sys.box_len, &mut buf);
        }
        golden.push((e, buf.clone()));
    }

    let natoms = sim.sys.natoms() as f64;
    let mut rows = Vec::new();
    for &k in ks {
        if k < 2 {
            continue; // k = 1 solves every step: zero stride error by construction
        }
        let (s1, s2) = (k, 2 * k);
        for extrap in [MtsExtrap::Hold, MtsExtrap::Linear] {
            let mut de_max = 0.0f64;
            let mut rms_max = 0.0f64;
            let mut cmp_max = 0.0f64;
            for m in 1..k {
                let w = m as f64 / k as f64;
                let (e_held, f_held): (f64, Vec<[f64; 3]>) = match extrap {
                    MtsExtrap::Hold => (golden[s2].0, golden[s2].1.clone()),
                    MtsExtrap::Linear => {
                        let e = golden[s2].0 + w * (golden[s2].0 - golden[s1].0);
                        let f = golden[s2]
                            .1
                            .iter()
                            .zip(&golden[s1].1)
                            .map(|(c, p)| {
                                [
                                    c[0] + w * (c[0] - p[0]),
                                    c[1] + w * (c[1] - p[1]),
                                    c[2] + w * (c[2] - p[2]),
                                ]
                            })
                            .collect();
                        (e, f)
                    }
                };
                let (e_exact, f_exact) = &golden[s2 + m];
                de_max = de_max.max((e_held - e_exact).abs() / natoms);
                let mut rms = 0.0;
                for (a, b) in f_held.iter().zip(f_exact) {
                    for d in 0..3 {
                        let diff = (a[d] - b[d]).abs();
                        rms += diff * diff;
                        cmp_max = cmp_max.max(diff);
                    }
                }
                rms_max = rms_max.max((rms / (3 * f_held.len()) as f64).sqrt());
            }
            rows.push(Row {
                name: format!("MTS-k{k}-{}(32x32x32)", extrap.name()),
                grid,
                energy_err_per_atom: de_max,
                force_rms_err: rms_max,
                force_max_err: cmp_max,
            });
        }
    }
    Ok(rows)
}

/// Print the MTS stride-error rows.
pub fn print_mts_rows(rows: &[Row]) {
    let mut t = Table::new(&[
        "Stride carry",
        "Error in energy [eV/atom]",
        "Site-force RMS err [eV/A]",
        "Site-force max err [eV/A]",
    ]);
    for r in rows {
        t.row(&[
            r.name.clone(),
            format!("{:.3e}", r.energy_err_per_atom),
            format!("{:.3e}", r.force_rms_err),
            format!("{:.3e}", r.force_max_err),
        ]);
    }
    println!("\n=== Table 1 (MTS): worst stride-carry error vs fresh solve ===");
    t.print();
    println!(
        "(held/extrapolated reciprocal forces at the worst intermediate step \
         of one k-step window, against a fresh double-precision 32^3 solve \
         on the same frame)"
    );
}

/// Print the Table-1 table.
pub fn print_rows(rows: &[Row]) {
    let mut t = Table::new(&[
        "Precision",
        "Error in energy [eV/atom]",
        "Force RMS err [eV/A]",
        "Force max err [eV/A]",
    ]);
    for r in rows {
        t.row(&[
            r.name.clone(),
            format!("{:.3e}", r.energy_err_per_atom),
            format!("{:.3e}", r.force_rms_err),
            format!("{:.3e}", r.force_max_err),
        ]);
    }
    println!("\n=== Table 1: single-step error vs golden reference ===");
    t.print();
    println!(
        "(reference = native f64 NN + exact direct k-space sum; the paper \
         compares against AIMD, so its Double row carries the model-vs-DFT \
         error while ours is the pure precision error — see EXPERIMENTS.md)"
    );
}
