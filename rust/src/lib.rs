//! # dplr — NNMD with long-range electrostatics, reproduced end to end
//!
//! Reproduction of *"Scaling Neural-Network-Based Molecular Dynamics with
//! Long-Range Electrostatic Interactions to 51 Nanoseconds per Day"*
//! (CS.DC 2025): the DPLR model (DeepPot-SE + Deep Wannier + PPPM), the
//! LAMMPS-like MD substrate it runs in, and the paper's coordination
//! contributions — utofu-FFT hardware-offloaded reductions, the 47+1
//! long/short-range overlap, and ring-based load balancing — on a simulated
//! Fugaku/TofuD substrate (see DESIGN.md).
//!
//! Layering (python never appears at runtime):
//!  * [`runtime`] loads the AOT HLO-text artifacts produced by
//!    `python/compile/aot.py` and runs them on a PJRT CPU client;
//!  * [`native`] is the framework-free inference path (paper section 3.4.2):
//!    the same DP/DW math hand-written in rust with analytic backprop;
//!  * [`engine`] assembles a full DPLR time step (DW forward -> PPPM ->
//!    DP + DW backward -> integrate) with optional real-thread overlap;
//!  * [`distpppm`] *executes* the paper's section-3.1 rank-decomposed,
//!    transpose-free FFT schedule over a virtual torus emulated on the
//!    worker pool (`dplr run --kspace dist`), or over real OS-process
//!    ranks ([`distpppm::process`], `--kspace dist --proc`) exchanging
//!    ring payloads through the length-framed [`transport`] layer;
//!  * [`simnet`]/[`tofu`]/[`mpisim`]/[`distfft`]/[`coordinator`]/
//!    [`perfmodel`] reproduce the paper's large-scale experiments on a
//!    calibrated discrete-event model of Fugaku.
//!
//! `docs/ARCHITECTURE.md` (repo root) maps paper sections to modules,
//! traces one MD step through the trait layer, and tabulates which paper
//! claims are reproduced numerically vs. analytically.
//! `docs/PERFORMANCE.md` is the performance companion: the bench
//! harness and its recorded keys, the bench-regression gate's verdict
//! semantics, and the baseline-refresh workflow.

// Style lints that fight the index-heavy numeric kernels in this crate
// (explicit `for i in 0..n` loops over multiple coupled arrays, physics
// notation single-letter names).  Correctness lints stay on.
// NOTE: this list is intentionally duplicated in the [lints.clippy]
// table of Cargo.toml (which also covers tests/benches/examples for
// `clippy --all-targets`, but is silently ignored by cargo < 1.74);
// keep the two in sync when changing either.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]
#![allow(clippy::many_single_char_names)]
#![allow(clippy::type_complexity)]
#![allow(clippy::manual_memcpy)]
#![allow(clippy::field_reassign_with_default)]
// Every public item must be documented; the CI `docs` job runs
// `cargo doc --no-deps` with RUSTDOCFLAGS="-D warnings" so this (and
// broken intra-doc links) fails the build instead of rotting silently.
#![warn(missing_docs)]

pub mod config;
pub mod coordinator;
pub mod distfft;
pub mod distpppm;
pub mod engine;
pub mod ewald;
pub mod fft;
pub mod md;
pub mod mpisim;
pub mod native;
pub mod neighbor;
pub mod perfmodel;
pub mod pool;
pub mod pppm;
pub mod runtime;
pub mod simnet;
pub mod tofu;
pub mod transport;
pub mod util;
pub mod experiments;
