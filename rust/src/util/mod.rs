//! Shared substrates the offline image forces us to hand-roll: JSON,
//! PRNG, CLI args, statistics, table printing and property-test helpers
//! (no serde / rand / clap / criterion / proptest available — see
//! DESIGN.md section 9).

pub mod args;
pub mod json;
pub mod propcheck;
pub mod rng;
pub mod stats;
pub mod table;
