//! Minimal JSON parser / writer (no serde in the offline image).
//!
//! Covers the full JSON grammar we exchange with the python build step:
//! manifest.json, weights.json (14 MB of nested float arrays — the parser is
//! written to stay allocation-light on those), fixtures.json and the
//! experiment config files.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value. Object keys keep insertion order irrelevant; we use
/// a BTreeMap for deterministic iteration.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any JSON number (always stored as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An array.
    Arr(Vec<Json>),
    /// An object (deterministically ordered).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document (trailing bytes are an error).
    pub fn parse(text: &str) -> Result<Json> {
        let b = text.as_bytes();
        let mut p = Parser { b, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != b.len() {
            bail!("trailing bytes at offset {}", p.i);
        }
        Ok(v)
    }

    /// Read and parse a JSON file, attributing errors to `path`.
    pub fn parse_file(path: &str) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {path}: {e}"))?;
        Self::parse(&text).map_err(|e| anyhow!("parsing {path}: {e}"))
    }

    /// Object field lookup (`None` for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field lookup that errors with the key name (for manifests).
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    /// The value as a number.
    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    /// The value as a non-negative integer.
    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("expected non-negative integer, got {x}");
        }
        Ok(x as usize)
    }

    /// The value as a (truncated) signed integer.
    pub fn as_i64(&self) -> Result<i64> {
        Ok(self.as_f64()? as i64)
    }

    /// The value as a string slice.
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    /// The value as a bool.
    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    /// The value as an array slice.
    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("expected array, got scalar/object"),
        }
    }

    /// Flatten a (possibly nested) numeric array into f64s.
    pub fn as_f64_vec(&self) -> Result<Vec<f64>> {
        let mut out = Vec::new();
        fn walk(v: &Json, out: &mut Vec<f64>) -> Result<()> {
            match v {
                Json::Num(x) => out.push(*x),
                Json::Arr(a) => {
                    for e in a {
                        walk(e, out)?;
                    }
                }
                _ => bail!("expected numeric array"),
            }
            Ok(())
        }
        walk(self, &mut out)?;
        Ok(out)
    }

    /// Flatten a (possibly nested) numeric array into i32s.
    pub fn as_i32_vec(&self) -> Result<Vec<i32>> {
        Ok(self.as_f64_vec()?.into_iter().map(|x| x as i32).collect())
    }

    // ---- writers -----------------------------------------------------

    /// Serialize with newline/indent formatting.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    /// Serialize without any whitespace.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x:e}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (k, e) in a.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    e.write(out, indent, pretty);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (k, (key, v)) in m.iter().enumerate() {
                    if k > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        for _ in 0..indent + 1 {
                            out.push(' ');
                        }
                    }
                    write_escaped(out, key);
                    out.push(':');
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !m.is_empty() {
                    out.push('\n');
                    for _ in 0..indent {
                        out.push(' ');
                    }
                }
                out.push('}');
            }
        }
    }

    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Build a numeric array from a float slice.
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Num(*x)).collect())
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn value(&mut self) -> Result<Json> {
        if self.i >= self.b.len() {
            bail!("unexpected end of input");
        }
        match self.b[self.i] {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at offset {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.i += 1; // {
        let mut m = BTreeMap::new();
        self.ws();
        if self.i < self.b.len() && self.b[self.i] == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            if self.i >= self.b.len() || self.b[self.i] != b':' {
                bail!("expected ':' at offset {}", self.i);
            }
            self.i += 1;
            self.ws();
            let v = self.value()?;
            m.insert(key, v);
            self.ws();
            match self.b.get(self.i) {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => bail!("expected ',' or '}}' at offset {}", self.i),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.i += 1; // [
        let mut a = Vec::new();
        self.ws();
        if self.i < self.b.len() && self.b[self.i] == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.b.get(self.i) {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => bail!("expected ',' or ']' at offset {}", self.i),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        if self.b.get(self.i) != Some(&b'"') {
            bail!("expected string at offset {}", self.i);
        }
        self.i += 1;
        let mut s = String::new();
        // fast path: copy runs of plain bytes
        loop {
            let start = self.i;
            while self.i < self.b.len() && self.b[self.i] != b'"' && self.b[self.i] != b'\\' {
                self.i += 1;
            }
            s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
            match self.b.get(self.i) {
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = *self
                        .b
                        .get(self.i)
                        .ok_or_else(|| anyhow!("bad escape at end"))?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(
                                self.b
                                    .get(self.i..self.i + 4)
                                    .ok_or_else(|| anyhow!("bad \\u escape"))?,
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape \\{}", c as char),
                    }
                }
                _ => bail!("unterminated string"),
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let tok = std::str::from_utf8(&self.b[start..self.i])?;
        let x: f64 = tok
            .parse()
            .map_err(|_| anyhow!("bad number '{tok}' at offset {start}"))?;
        Ok(Json::Num(x))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str().unwrap(), "x\ny");
        let a = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[1].as_f64().unwrap(), 2.0);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"x": [1.5, -2, true, "a\"b"], "y": {"z": []}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(j, j2);
        let j3 = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, j3);
    }

    #[test]
    fn flattens_nested_numeric_arrays() {
        let j = Json::parse("[[1,2],[3,4],[5,6]]").unwrap();
        assert_eq!(j.as_f64_vec().unwrap(), vec![1., 2., 3., 4., 5., 6.]);
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "Aé");
    }
}
