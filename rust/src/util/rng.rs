//! Deterministic PRNG (xoshiro256**) — no `rand` crate in the offline image.

/// xoshiro256** seeded via splitmix64; good statistical quality, tiny code.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box-Muller normal
    spare: Option<f64>,
}

impl Rng {
    /// Seed the generator (any value, including 0, is fine).
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion of the seed
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
            spare: None,
        }
    }

    /// Next raw 64-bit output of xoshiro256**.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare.take() {
            return z;
        }
        loop {
            let u = self.uniform();
            if u <= f64::MIN_POSITIVE {
                continue;
            }
            let v = self.uniform();
            let r = (-2.0 * u.ln()).sqrt();
            let (sin, cos) = (2.0 * std::f64::consts::PI * v).sin_cos();
            self.spare = Some(r * sin);
            return r * cos;
        }
    }

    /// Random unit vector in R^3.
    pub fn unit3(&mut self) -> [f64; 3] {
        loop {
            let v = [self.normal(), self.normal(), self.normal()];
            let n = (v[0] * v[0] + v[1] * v[1] + v[2] * v[2]).sqrt();
            if n > 1e-12 {
                return [v[0] / n, v[1] / n, v[2] / n];
            }
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn uniform_in_range_and_roughly_uniform() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut mean = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            mean += u;
        }
        mean /= n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut m, mut v) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            m += z;
            v += z * z;
        }
        m /= n as f64;
        v = v / n as f64 - m * m;
        assert!(m.abs() < 0.02, "mean {m}");
        assert!((v - 1.0).abs() < 0.05, "var {v}");
    }

    #[test]
    fn unit3_has_unit_norm() {
        let mut r = Rng::new(3);
        for _ in 0..50 {
            let v = r.unit3();
            let n = (v[0] * v[0] + v[1] * v[1] + v[2] * v[2]).sqrt();
            assert!((n - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
