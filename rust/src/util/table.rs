//! Plain-text table printer for experiment outputs (paper-style rows).

/// A monospace table: headers + rows, rendered with aligned columns.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Start a table with the given column headers.
    pub fn new(headers: &[&str]) -> Table {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (must match the header arity).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render to an aligned plain-text string.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut w = vec![0usize; ncol];
        for (i, h) in self.headers.iter().enumerate() {
            w[i] = h.chars().count();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        let line = |out: &mut String, cells: &[String]| {
            out.push('|');
            for (i, c) in cells.iter().enumerate() {
                out.push(' ');
                out.push_str(c);
                for _ in c.chars().count()..w[i] {
                    out.push(' ');
                }
                out.push_str(" |");
            }
            out.push('\n');
        };
        line(&mut out, &self.headers);
        out.push('|');
        for wi in &w {
            for _ in 0..wi + 2 {
                out.push('-');
            }
            out.push('|');
        }
        out.push('\n');
        for r in &self.rows {
            line(&mut out, r);
        }
        out
    }

    /// Render and print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format seconds adaptively (ns/us/ms/s).
pub fn fmt_time(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1} ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2} us", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2} ms", secs * 1e3)
    } else {
        format!("{secs:.2} s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer".into(), "22".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines.iter().all(|l| l.len() == lines[0].len()));
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2e-9).ends_with("ns"));
        assert!(fmt_time(2e-6).ends_with("us"));
        assert!(fmt_time(2e-3).ends_with("ms"));
        assert!(fmt_time(2.0).ends_with(" s"));
    }
}
