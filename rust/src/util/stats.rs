//! Small statistics + timing helpers used by benches and experiments.

use std::time::Instant;

#[derive(Debug, Clone, Default)]
/// Summary statistics of a sample (used for bench timings).
pub struct Summary {
    /// Sample count.
    pub n: usize,
    /// Arithmetic mean.
    pub mean: f64,
    /// Population standard deviation.
    pub std: f64,
    /// Smallest sample.
    pub min: f64,
    /// Largest sample.
    pub max: f64,
    /// Median (nearest-rank).
    pub p50: f64,
    /// 95th percentile (nearest-rank).
    pub p95: f64,
}

/// Summarize a sample (empty input gives a zeroed summary).
pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary::default();
    }
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let q = |p: f64| s[((p * (n - 1) as f64).round() as usize).min(n - 1)];
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: s[0],
        max: s[n - 1],
        p50: q(0.5),
        p95: q(0.95),
    }
}

/// Time a closure `reps` times after `warmup` runs; returns per-rep seconds.
pub fn time_reps<F: FnMut()>(warmup: usize, reps: usize, mut f: F) -> Vec<f64> {
    for _ in 0..warmup {
        f();
    }
    let mut out = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t = Instant::now();
        f();
        out.push(t.elapsed().as_secs_f64());
    }
    out
}

/// Relative L2 error ||a-b|| / max(||b||, eps).
pub fn rel_l2(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut num = 0.0;
    let mut den = 0.0;
    for (x, y) in a.iter().zip(b) {
        num += (x - y) * (x - y);
        den += y * y;
    }
    (num.sqrt()) / den.sqrt().max(1e-300)
}

/// Max absolute difference.
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

/// Root-mean-square of a slice.
pub fn rms(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x * x).sum::<f64>() / xs.len() as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
    }

    #[test]
    fn rel_l2_zero_for_equal() {
        let a = [1.0, -2.0, 3.0];
        assert_eq!(rel_l2(&a, &a), 0.0);
        assert!(rel_l2(&[1.0, 0.0], &[0.0, 1.0]) > 0.5);
    }

    #[test]
    fn rms_known() {
        assert!((rms(&[3.0, 4.0]) - (12.5f64).sqrt()).abs() < 1e-12);
    }
}
