//! Tiny CLI argument parser (no clap in the offline image).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments; used by the `dplr` binary and the bench harnesses.

use anyhow::{anyhow, Result};
use std::collections::BTreeMap;

/// Parsed command line: positionals plus `--key value` flags.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// Arguments that are not `--flags`, in order.
    pub positional: Vec<String>,
    /// Flag map; bare `--flag` stores the value `"true"`.
    pub flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse an argument iterator (`--key value`, `--key=value`, bare
    /// `--flag`, positionals).
    pub fn parse(argv: impl Iterator<Item = String>) -> Args {
        let mut out = Args::default();
        let argv: Vec<String> = argv.collect();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.flags.insert(stripped.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    /// Parse the process arguments (program name skipped).
    pub fn from_env() -> Args {
        Self::parse(std::env::args().skip(1))
    }

    /// Raw flag value, if present.
    pub fn str_opt(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// Flag value or `default`, as an owned string.
    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.str_opt(key).unwrap_or(default).to_string()
    }

    /// Integer flag or `default`; errors on unparsable values.
    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    /// `u64` flag or `default` (e.g. RNG seeds, which must round-trip the
    /// full 64-bit range); errors on unparsable values.
    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects an unsigned integer, got '{v}'")),
        }
    }

    /// Float flag or `default`; errors on unparsable values.
    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.flags.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--{key} expects a number, got '{v}'")),
        }
    }

    /// Boolean flag: present as bare `--flag`, `true`, `1` or `yes`.
    pub fn bool(&self, key: &str) -> bool {
        matches!(self.flags.get(key).map(|s| s.as_str()), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(v: &[&str]) -> Args {
        Args::parse(v.iter().map(|s| s.to_string()))
    }

    #[test]
    fn parses_mixed_styles() {
        let a = parse(&["run", "--steps", "100", "--grid=32", "--overlap", "--out", "x.json"]);
        assert_eq!(a.positional, vec!["run"]);
        assert_eq!(a.usize_or("steps", 0).unwrap(), 100);
        assert_eq!(a.usize_or("grid", 0).unwrap(), 32);
        assert!(a.bool("overlap"));
        assert_eq!(a.str_or("out", ""), "x.json");
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["x"]);
        assert_eq!(a.usize_or("steps", 7).unwrap(), 7);
        assert_eq!(a.f64_or("dt", 1.5).unwrap(), 1.5);
        assert!(!a.bool("overlap"));
    }

    #[test]
    fn bad_value_is_error() {
        let a = parse(&["--steps", "abc"]);
        assert!(a.usize_or("steps", 0).is_err());
    }

    #[test]
    fn u64_round_trips_the_full_range() {
        let big = u64::MAX.to_string();
        let a = parse(&["--seed", &big]);
        assert_eq!(a.u64_or("seed", 0).unwrap(), u64::MAX);
        assert_eq!(parse(&["x"]).u64_or("seed", 42).unwrap(), 42);
        assert!(parse(&["--seed", "-3"]).u64_or("seed", 0).is_err());
    }
}
