//! Property-based testing helper (no proptest in the offline image).
//!
//! `check(seed, cases, gen, prop)` runs `prop` on `cases` generated inputs
//! and reports the failing seed + case index for reproduction.  Generators
//! take an [`Rng`] so every case is deterministic given (seed, index).

use super::rng::Rng;

/// Run a property over `cases` generated inputs; panics with the case seed
/// on the first failure so it can be replayed exactly.
pub fn check<T, G, P>(seed: u64, cases: usize, mut generate: G, mut prop: P)
where
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
    T: std::fmt::Debug,
{
    for case in 0..cases {
        let case_seed = seed
            .wrapping_mul(0x100000001B3)
            .wrapping_add(case as u64);
        let mut rng = Rng::new(case_seed);
        let input = generate(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed (seed={seed}, case={case}, case_seed={case_seed}):\n  {msg}\n  input: {input:?}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check(1, 50, |r| r.range(0.0, 10.0), |x| {
            if *x >= 0.0 && *x < 10.0 {
                Ok(())
            } else {
                Err(format!("out of range: {x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failure() {
        check(2, 50, |r| r.below(100), |x| {
            if *x < 90 {
                Ok(())
            } else {
                Err("too big".to_string())
            }
        });
    }
}
