//! Quickstart: 20 DPLR MD steps on a 64-water box with the framework-free
//! backend.  Run `make artifacts` once, then:
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use dplr::engine::{KspaceConfig, Simulation};
use dplr::md::water::water_box;
use dplr::native::NativeModel;
use dplr::runtime::manifest::artifacts_dir;
use dplr::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // 1. build a 64-molecule water box at ~1 g/cc and 300 K
    let mut sys = water_box(64, 42);
    let mut rng = Rng::new(7);
    sys.thermalize(300.0, &mut rng);

    // 2. assemble the simulation: the DPLR model (DP + DW nets exported by
    //    `make artifacts`) as the short-range provider, PPPM sized from the
    //    box as the k-space solver, NVT at 300 K, 1 fs steps — progress
    //    reporting rides the observer hook instead of a hand-rolled loop
    let mut sim = Simulation::builder(sys)
        .dt_fs(1.0)
        .thermostat(300.0, 0.5)
        .kspace(KspaceConfig::PppmAuto { alpha: 0.3 })
        .short_range(Box::new(NativeModel::load(&artifacts_dir())?))
        .observe(|step, _, o| {
            println!(
                "step {step:>3}: T = {:7.1} K   E_sr = {:9.3} eV   E_Gt = {:8.3} eV",
                o.temperature, o.e_sr, o.e_gt
            );
        })
        .build()?;

    // 3. relax the fresh lattice, then run production steps
    sim.quench(20)?;
    sim.reheat(300.0, 3);
    sim.run(20)?;
    println!("quickstart OK");
    Ok(())
}
