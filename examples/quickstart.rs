//! Quickstart: 20 DPLR MD steps on a 64-water box with the framework-free
//! backend.  Run `make artifacts` once, then:
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use dplr::engine::{Backend, DplrEngine, EngineConfig};
use dplr::md::water::water_box;
use dplr::native::NativeModel;
use dplr::runtime::manifest::artifacts_dir;
use dplr::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // 1. build a 64-molecule water box at ~1 g/cc and 300 K
    let mut sys = water_box(64, 42);
    let mut rng = Rng::new(7);
    sys.thermalize(300.0, &mut rng);

    // 2. load the DPLR model (DP + DW nets exported by `make artifacts`)
    let backend = Backend::Native(NativeModel::load(&artifacts_dir())?);

    // 3. engine: PPPM mesh sized from the box, NVT at 300 K, 1 fs steps
    let cfg = EngineConfig::default_for(sys.box_len, 0.3);
    let mut eng = DplrEngine::new(sys, cfg, backend);

    // 4. relax the fresh lattice, then run production steps
    eng.quench(20)?;
    eng.reheat(300.0, 3);
    for step in 1..=20 {
        eng.step()?;
        let o = eng.last_obs.unwrap();
        println!(
            "step {step:>3}: T = {:7.1} K   E_sr = {:9.3} eV   E_Gt = {:8.3} eV",
            o.temperature, o.e_sr, o.e_gt
        );
    }
    println!("quickstart OK");
    Ok(())
}
