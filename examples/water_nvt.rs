//! End-to-end driver (DESIGN.md section 6, row E2E): the paper's headline
//! 564-atom water system on the full DPLR stack — DW forward, PPPM with
//! Wannier centroids, DP short range, DW backprop, NVT integration — with
//! the section 3.2 overlap running on real threads, reporting ns/day and
//! energy statistics.  Results are recorded in EXPERIMENTS.md.
//!
//! ```sh
//! cargo run --release --example water_nvt -- [steps]
//! ```

use dplr::engine::{Backend, DplrEngine, EngineConfig, StepTimes};
use dplr::md::units::ns_per_day;
use dplr::md::water::replicated_base_box;
use dplr::native::NativeModel;
use dplr::runtime::manifest::artifacts_dir;
use dplr::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);
    // the paper's base box: 188 molecules, 20.85 A
    let mut sys = replicated_base_box([1, 1, 1], 1);
    let mut rng = Rng::new(11);
    sys.thermalize(300.0, &mut rng);
    println!(
        "system: {} atoms ({} molecules + WCs), box {:.2} A",
        sys.natoms(),
        sys.nmol,
        sys.box_len[0]
    );
    let backend = Backend::Native(NativeModel::load(&artifacts_dir())?);
    let mut cfg = EngineConfig::default_for(sys.box_len, 0.3);
    cfg.overlap = true; // PPPM on a dedicated thread (paper section 3.2)
    let mut eng = DplrEngine::new(sys, cfg, backend);

    eng.quench(30)?;
    eng.reheat(300.0, 5);

    let mut acc = StepTimes::default();
    let t0 = std::time::Instant::now();
    let mut temps = Vec::new();
    let mut energies = Vec::new();
    for s in 1..=steps {
        let t = eng.step()?;
        acc.add(&t);
        let o = eng.last_obs.unwrap();
        temps.push(o.temperature);
        energies.push(o.e_sr + o.e_gt + o.kinetic);
        if s % 50 == 0 {
            println!(
                "step {s:>5}: T {:7.1} K   E_tot {:11.3} eV   cons {:12.4}",
                o.temperature,
                o.e_sr + o.e_gt + o.kinetic,
                o.conserved
            );
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let per_step = wall / steps as f64;
    let half = temps.len() / 2;
    let mean_t: f64 = temps[half..].iter().sum::<f64>() / (temps.len() - half) as f64;
    let mean_e: f64 = energies[half..].iter().sum::<f64>() / (energies.len() - half) as f64;
    println!("\n=== E2E result (564-atom water, full DPLR stack, overlap on) ===");
    println!("steps           : {steps}");
    println!("wall time       : {wall:.2} s");
    println!("per step        : {:.2} ms", per_step * 1e3);
    println!("this host       : {:.3} ns/day", ns_per_day(per_step, 1.0));
    println!("<T> second half : {mean_t:.1} K");
    println!("<E> second half : {mean_e:.3} eV");
    println!(
        "breakdown/step  : dw_fwd {:.2} ms | kspace(thread) {:.2} ms | dp {:.2} ms | dw_bwd {:.2} ms | nlist {:.2} ms",
        1e3 * acc.dw_fwd / steps as f64,
        1e3 * acc.kspace / steps as f64,
        1e3 * acc.dp_all / steps as f64,
        1e3 * acc.dw_bwd / steps as f64,
        1e3 * acc.nlist / steps as f64,
    );
    println!(
        "(the paper's 51 ns/day is 12 Fugaku nodes = 564 A64FX cores; this \
         is one CPU — see `dplr weakscaling` for the scaled reproduction)"
    );
    Ok(())
}
