//! End-to-end driver (DESIGN.md section 6, row E2E): the paper's headline
//! 564-atom water system on the full DPLR stack — DW forward, PPPM with
//! Wannier centroids, DP short range, DW backprop, NVT integration — with
//! the section 3.2 overlap running on real threads, reporting ns/day and
//! energy statistics.  Results are recorded in EXPERIMENTS.md.
//!
//! ```sh
//! cargo run --release --example water_nvt -- [steps]
//! ```

use dplr::engine::{KspaceConfig, Simulation, StepRecorder};
use dplr::md::units::ns_per_day;
use dplr::md::water::replicated_base_box;
use dplr::native::NativeModel;
use dplr::runtime::manifest::artifacts_dir;
use dplr::util::rng::Rng;
use std::sync::{Arc, Mutex};

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);
    // the paper's base box: 188 molecules, 20.85 A
    let mut sys = replicated_base_box([1, 1, 1], 1);
    let mut rng = Rng::new(11);
    sys.thermalize(300.0, &mut rng);
    println!(
        "system: {} atoms ({} molecules + WCs), box {:.2} A",
        sys.natoms(),
        sys.nmol,
        sys.box_len[0]
    );

    // timing + statistics flow through observers: the shared recorder sums
    // the per-step breakdown, the closure samples T/E and prints progress
    let rec = StepRecorder::new();
    let samples: Arc<Mutex<(Vec<f64>, Vec<f64>)>> = Arc::new(Mutex::new((Vec::new(), Vec::new())));
    let sink = samples.clone();
    let mut sim = Simulation::builder(sys)
        .dt_fs(1.0)
        .thermostat(300.0, 0.5)
        .overlap(true) // PPPM on a dedicated thread (paper section 3.2)
        .kspace(KspaceConfig::PppmAuto { alpha: 0.3 })
        .short_range(Box::new(NativeModel::load(&artifacts_dir())?))
        .observer(Box::new(rec.clone()))
        .observe(move |step, _, o| {
            let mut s = sink.lock().unwrap();
            s.0.push(o.temperature);
            s.1.push(o.e_sr + o.e_gt + o.kinetic);
            if step % 50 == 0 {
                println!(
                    "step {step:>5}: T {:7.1} K   E_tot {:11.3} eV   cons {:12.4}",
                    o.temperature,
                    o.e_sr + o.e_gt + o.kinetic,
                    o.conserved
                );
            }
        })
        .build()?;

    sim.quench(30)?;
    sim.reheat(300.0, 5);

    let t0 = std::time::Instant::now();
    sim.run(steps)?;
    let wall = t0.elapsed().as_secs_f64();
    let per_step = wall / steps as f64;
    let acc = rec.totals();
    let (temps, energies) = samples.lock().unwrap().clone();
    let half = temps.len() / 2;
    let mean_t: f64 = temps[half..].iter().sum::<f64>() / (temps.len() - half) as f64;
    let mean_e: f64 = energies[half..].iter().sum::<f64>() / (energies.len() - half) as f64;
    println!("\n=== E2E result (564-atom water, full DPLR stack, overlap on) ===");
    println!("steps           : {steps}");
    println!("wall time       : {wall:.2} s");
    println!("per step        : {:.2} ms", per_step * 1e3);
    println!("this host       : {:.3} ns/day", ns_per_day(per_step, 1.0));
    println!("<T> second half : {mean_t:.1} K");
    println!("<E> second half : {mean_e:.3} eV");
    println!(
        "breakdown/step  : dw_fwd {:.2} ms | kspace(thread) {:.2} ms | dp {:.2} ms | dw_bwd {:.2} ms | nlist {:.2} ms",
        1e3 * acc.dw_fwd / steps as f64,
        1e3 * acc.kspace / steps as f64,
        1e3 * acc.dp_all / steps as f64,
        1e3 * acc.dw_bwd / steps as f64,
        1e3 * acc.nlist / steps as f64,
    );
    println!(
        "(the paper's 51 ns/day is 12 Fugaku nodes = 564 A64FX cores; this \
         is one CPU — see `dplr weakscaling` for the scaled reproduction)"
    );
    Ok(())
}
