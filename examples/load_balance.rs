//! Ring-based load balancing demo (paper section 3.3): builds the paper's
//! replicated 96-node workload, shows the real per-node atom census, runs
//! Algorithm 1 over the serpentine ring and prints the migration plan.
//!
//! ```sh
//! cargo run --release --example load_balance
//! ```

use dplr::coordinator::ringlb::{imbalance, ring_migration, serpentine_ring};
use dplr::coordinator::spatial::node_loads;
use dplr::md::water::replicated_base_box;
use dplr::tofu::Torus;

fn main() {
    // the Fig 9 workload: 188-water base box replicated (2,2,2) on 96 nodes
    let sys = replicated_base_box([2, 2, 2], 1);
    let torus = Torus::new([4, 6, 4]);
    let loads = node_loads(&sys, &torus);
    let goal = sys.natoms().div_ceil(torus.nodes());

    println!(
        "workload: {} atoms on {} nodes (goal {} atoms/node)",
        sys.natoms(),
        torus.nodes(),
        goal
    );
    let min = loads.iter().min().unwrap();
    let max = loads.iter().max().unwrap();
    println!(
        "before: min {min}  max {max}  imbalance (max/mean) {:.3}",
        imbalance(&loads)
    );

    let order = serpentine_ring(&torus);
    let ring_loads: Vec<usize> = order.iter().map(|&n| loads[n]).collect();
    let mig = ring_migration(&ring_loads, goal);

    let moved: usize = mig.send.iter().sum();
    println!(
        "ring migration: {} atoms moved (each exactly 1 torus hop), {} clamped ranks",
        moved, mig.clamped
    );
    println!(
        "after:  min {}  max {}  imbalance {:.3}",
        mig.after.iter().min().unwrap(),
        mig.after.iter().max().unwrap(),
        imbalance(&mig.after)
    );

    // show the first stretch of the ring like the paper's Fig 6
    println!("\nring position | load -> after (send downstream)");
    for pos in 0..16.min(mig.after.len()) {
        println!(
            "{:>13} | {:>4} -> {:<5} ({})",
            pos, ring_loads[pos], mig.after[pos], mig.send[pos]
        );
    }
    println!("...");
}
