//! Scaling study on the simulated Fugaku: sweeps the optimization ladder
//! (Fig 9) and weak scaling (Fig 10) in one run — a compact view of every
//! coordination contribution of the paper working together.
//!
//! ```sh
//! cargo run --release --example scaling_study
//! ```

use dplr::config::MachineConfig;
use dplr::experiments::{fig10_weak, fig9_stepopt};
use dplr::perfmodel::CostTable;

fn main() {
    let machine = MachineConfig::default();
    let cost = CostTable::default();

    for (nodes, dims, rep) in fig9_stepopt::paper_configs() {
        let stages = fig9_stepopt::run(dims, rep, &cost, &machine);
        fig9_stepopt::print_stages(nodes, &stages);
        let last = stages.last().unwrap();
        println!(
            "=> {nodes} nodes fully optimized: {:.2} ms/step, {:.1}x vs baseline\n",
            1e3 * last.breakdown.total(),
            last.speedup_vs_baseline
        );
    }

    let pts = fig10_weak::run(&cost, &machine);
    fig10_weak::print_points(&pts);
}
