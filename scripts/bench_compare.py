#!/usr/bin/env python3
"""Bench-regression gate: merge per-bench JSON outputs and compare them
against the checked-in BENCH_baseline.json.

Usage:
    bench_compare.py --baseline BENCH_baseline.json --out BENCH_ci.json \
        [--tol 0.25] BENCH_hotpath.json BENCH_fig8_fft.json ...

Each input is what the rust benches write with `--json PATH`:
    {"bench": "<name>", "threads": N, "quick": true, "results": {key: secs}}

The baseline has the shape
    {"tolerance": 0.25,
     "exact": {"<bench name>": ["glob", ...]} | absent,
     "<bench name>": {key: secs} | null, ...}
A section that is null (the bootstrap state) is reported informationally
and never fails — refresh it by running the benches on a reference host
and merging the measured sections in (scripts/refresh_baseline.py, or the
bench-baseline workflow; see rust/README.md, "Refreshing the bench
baseline").

Keys matching an "exact" glob pattern for their bench are *deterministic*
outputs (simulated seconds from the DES model, not wall time) and are
gated at 0% tolerance: any relative deviation beyond EXACT_EPS (libm
last-ulp / JSON round-trip noise) fails, in both directions.  A
deterministic key missing from a non-null baseline section fails too —
silence must not read as coverage.

All key deltas are collected and reported in ONE pass: wall-time keys
missing from the baseline (e.g. a freshly added bench section) and
wall-time baseline keys no longer emitted are printed together in a
consolidated block (informational — refresh via refresh_baseline.py /
the bench-baseline job), so a baseline refresh never needs more than a
single compare run to see everything that changed.

Exit status: 1 on any exact mismatch or any wall-time key slower than
baseline * (1 + tol), 0 otherwise.  Wall-time keys faster than
baseline * (1 - tol) print a hint to refresh the baseline but do not fail
(that gate is one-sided: it exists to catch regressions).  The merged
measurements + verdicts are written to --out for the CI artifact upload.
"""

import argparse
import fnmatch
import json
import os
import sys

# relative epsilon for "0% tolerance" deterministic keys
EXACT_EPS = 1e-9


def is_exact(baseline: dict, bench: str, key: str) -> bool:
    pats = (baseline.get("exact") or {}).get(bench, [])
    return any(fnmatch.fnmatch(key, p) for p in pats)


def write_step_summary(verdicts: dict, tol: float, failures: list) -> None:
    """Render the verdict table as GitHub-flavoured markdown.

    Appended to $GITHUB_STEP_SUMMARY when set (the CI job-summary pane);
    printed to stdout otherwise so local runs see the same table.
    """
    def num(x):
        return f"{x:.6g}" if isinstance(x, (int, float)) else "-"

    lines = [
        "## Bench regression gate",
        "",
        f"Wall-time tolerance +-{tol:.0%}; `exact`-gated keys at 0% "
        f"(rel eps {EXACT_EPS:g}).",
        "",
        "| bench | key | measured | baseline | ratio | verdict |",
        "|---|---|---:|---:|---:|---|",
    ]
    for bench in sorted(verdicts):
        for key in sorted(verdicts[bench]):
            v = verdicts[bench][key]
            ratio = v.get("ratio")
            lines.append(
                f"| {bench} | {key} | {num(v.get('secs'))} "
                f"| {num(v.get('baseline'))} "
                f"| {f'{ratio:.2f}x' if ratio is not None else '-'} "
                f"| {v['verdict']} |")
    lines += ["", "**Gate: FAILED**" if failures else "**Gate: passed**", ""]
    text = "\n".join(lines)
    path = os.environ.get("GITHUB_STEP_SUMMARY")
    if path:
        with open(path, "a") as f:
            f.write(text)
    else:
        print(text)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("inputs", nargs="+", help="per-bench JSON files")
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--out", required=True)
    # default None so the baseline file's "tolerance" field is the fallback
    ap.add_argument("--tol", type=float, default=None)
    args = ap.parse_args()

    with open(args.baseline) as f:
        baseline = json.load(f)
    tol = args.tol if args.tol is not None else baseline.get("tolerance", 0.25)

    merged = {}
    for path in args.inputs:
        with open(path) as f:
            doc = json.load(f)
        merged[doc["bench"]] = doc.get("results", {})

    failures = []
    faster = []
    verdicts = {}
    # consolidated key-delta report: every key the benches emit that the
    # baseline lacks, and every baseline key the benches no longer emit —
    # collected across ALL benches and printed in one block, so a baseline
    # refresh after adding a bench section is a single pass instead of a
    # fix-one-key-rerun loop
    missing_in_baseline = []   # emitted, no baseline value (wall-time only)
    stale_in_baseline = []     # baselined, no longer emitted (wall-time only)
    for bench, results in sorted(merged.items()):
        base = baseline.get(bench)
        if base is None:
            print(f"[bench-compare] {bench}: no baseline yet (bootstrap) — "
                  f"recorded {len(results)} keys, nothing to gate")
            verdicts[bench] = {k: {"secs": v, "verdict": "no-baseline"}
                               for k, v in results.items()}
            missing_in_baseline.extend(f"{bench}/{k}" for k in sorted(results))
            continue
        verdicts[bench] = {}
        for key, secs in sorted(results.items()):
            ref = base.get(key)
            if is_exact(baseline, bench, key):
                if ref is None:
                    verdicts[bench][key] = {"secs": secs,
                                            "verdict": "EXACT-MISSING"}
                    failures.append(
                        f"{bench}/{key}: deterministic key has no baseline "
                        f"value — regenerate (scripts/fig8_model_baseline.py)")
                elif abs(secs - ref) > EXACT_EPS * max(abs(ref), 1e-300):
                    verdicts[bench][key] = {"secs": secs, "baseline": ref,
                                            "verdict": "EXACT-MISMATCH"}
                    failures.append(
                        f"{bench}/{key}: deterministic output changed: "
                        f"{secs!r} vs baseline {ref!r}")
                else:
                    verdicts[bench][key] = {"secs": secs, "baseline": ref,
                                            "verdict": "exact-ok"}
                continue
            if ref is None or ref <= 0:
                verdicts[bench][key] = {"secs": secs, "verdict": "no-baseline"}
                missing_in_baseline.append(f"{bench}/{key}")
                continue
            ratio = secs / ref
            if ratio > 1.0 + tol:
                verdicts[bench][key] = {"secs": secs, "baseline": ref,
                                        "ratio": ratio, "verdict": "REGRESSION"}
                failures.append(f"{bench}/{key}: {secs*1e3:.2f} ms vs baseline "
                                f"{ref*1e3:.2f} ms ({ratio:.2f}x > {1+tol:.2f}x)")
            elif ratio < 1.0 - tol:
                verdicts[bench][key] = {"secs": secs, "baseline": ref,
                                        "ratio": ratio, "verdict": "faster"}
                faster.append(f"{bench}/{key}: {ratio:.2f}x of baseline")
            else:
                verdicts[bench][key] = {"secs": secs, "baseline": ref,
                                        "ratio": ratio, "verdict": "ok"}
        # the reverse direction: a deterministic baseline key the bench no
        # longer emits is a silent coverage loss, not a pass; a wall-time
        # key that vanished is reported (informationally) for the refresh
        for key in sorted(base):
            if key in results:
                continue
            if is_exact(baseline, bench, key):
                verdicts[bench][key] = {"baseline": base[key],
                                        "verdict": "EXACT-NOT-MEASURED"}
                failures.append(
                    f"{bench}/{key}: deterministic baseline key was not "
                    f"emitted by the bench — model/bench changed without a "
                    f"baseline regen (scripts/fig8_model_baseline.py)")
            else:
                verdicts[bench][key] = {"baseline": base[key],
                                        "verdict": "stale-baseline"}
                stale_in_baseline.append(f"{bench}/{key}")

    out = {"tolerance": tol, "measurements": merged, "comparison": verdicts}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
    print(f"[bench-compare] wrote {args.out}")

    if missing_in_baseline or stale_in_baseline:
        refresh_cmd = ("python3 scripts/refresh_baseline.py --baseline "
                       f"{args.baseline} {' '.join(args.inputs)}")
        print("[bench-compare] key delta vs baseline (all benches, one "
              "pass).  Every key below is an UN-GATED WALL-TIME key "
              "(deterministic keys fail above instead of landing here) — "
              "e.g. the fig8 measured_proc_resident_* family stays in this "
              "state until baselined.  Refresh by running the "
              "bench-baseline workflow_dispatch job on the reference "
              "runner, or locally with exactly:")
        print(f"  {refresh_cmd}")
        for key in missing_in_baseline:
            print(f"  missing in baseline (un-gated wall key): {key}")
        for key in stale_in_baseline:
            print(f"  stale in baseline (no longer emitted): {key}")
    if faster:
        print("[bench-compare] faster than baseline (consider refreshing "
              "BENCH_baseline.json):")
        for line in faster:
            print(f"  {line}")
    write_step_summary(verdicts, tol, failures)
    if failures:
        print("[bench-compare] FAILURES (wall-time regressions / exact "
              "mismatches):", file=sys.stderr)
        for line in failures:
            print(f"  {line}", file=sys.stderr)
        return 1
    print("[bench-compare] no regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
