#!/usr/bin/env python3
"""Bench-regression gate: merge per-bench JSON outputs and compare them
against the checked-in BENCH_baseline.json.

Usage:
    bench_compare.py --baseline BENCH_baseline.json --out BENCH_ci.json \
        [--tol 0.25] BENCH_hotpath.json BENCH_fig8_fft.json ...

Each input is what the rust benches write with `--json PATH`:
    {"bench": "<name>", "threads": N, "quick": true, "results": {key: secs}}

The baseline has the shape
    {"tolerance": 0.25, "<bench name>": {key: secs} | null, ...}
A section that is null (the bootstrap state) is reported informationally
and never fails — refresh it by running the benches on a reference host
and copying the measured sections in (see rust/README.md, "Refreshing the
bench baseline").

Exit status: 1 if any measured key is slower than baseline * (1 + tol),
0 otherwise.  Keys faster than baseline * (1 - tol) print a hint to
refresh the baseline but do not fail (the gate is one-sided: it exists to
catch regressions).  The merged measurements + verdicts are written to
--out for the CI artifact upload.
"""

import argparse
import json
import sys


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("inputs", nargs="+", help="per-bench JSON files")
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--out", required=True)
    # default None so the baseline file's "tolerance" field is the fallback
    ap.add_argument("--tol", type=float, default=None)
    args = ap.parse_args()

    with open(args.baseline) as f:
        baseline = json.load(f)
    tol = args.tol if args.tol is not None else baseline.get("tolerance", 0.25)

    merged = {}
    for path in args.inputs:
        with open(path) as f:
            doc = json.load(f)
        merged[doc["bench"]] = doc.get("results", {})

    failures = []
    faster = []
    verdicts = {}
    for bench, results in sorted(merged.items()):
        base = baseline.get(bench)
        if base is None:
            print(f"[bench-compare] {bench}: no baseline yet (bootstrap) — "
                  f"recorded {len(results)} keys, nothing to gate")
            verdicts[bench] = {k: {"secs": v, "verdict": "no-baseline"}
                               for k, v in results.items()}
            continue
        verdicts[bench] = {}
        for key, secs in sorted(results.items()):
            ref = base.get(key)
            if ref is None or ref <= 0:
                verdicts[bench][key] = {"secs": secs, "verdict": "no-baseline"}
                continue
            ratio = secs / ref
            if ratio > 1.0 + tol:
                verdicts[bench][key] = {"secs": secs, "baseline": ref,
                                        "ratio": ratio, "verdict": "REGRESSION"}
                failures.append(f"{bench}/{key}: {secs*1e3:.2f} ms vs baseline "
                                f"{ref*1e3:.2f} ms ({ratio:.2f}x > {1+tol:.2f}x)")
            elif ratio < 1.0 - tol:
                verdicts[bench][key] = {"secs": secs, "baseline": ref,
                                        "ratio": ratio, "verdict": "faster"}
                faster.append(f"{bench}/{key}: {ratio:.2f}x of baseline")
            else:
                verdicts[bench][key] = {"secs": secs, "baseline": ref,
                                        "ratio": ratio, "verdict": "ok"}

    out = {"tolerance": tol, "measurements": merged, "comparison": verdicts}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
    print(f"[bench-compare] wrote {args.out}")

    if faster:
        print("[bench-compare] faster than baseline (consider refreshing "
              "BENCH_baseline.json):")
        for line in faster:
            print(f"  {line}")
    if failures:
        print("[bench-compare] WALL-TIME REGRESSIONS:", file=sys.stderr)
        for line in failures:
            print(f"  {line}", file=sys.stderr)
        return 1
    print("[bench-compare] no regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
