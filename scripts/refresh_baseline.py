#!/usr/bin/env python3
"""Merge measured bench JSONs into BENCH_baseline.json.

Usage:
    refresh_baseline.py --baseline BENCH_baseline.json \
        BENCH_hotpath.json BENCH_fig8_fft.json [...]

Each input is what the rust benches write with `--json PATH`
({"bench": name, "results": {key: secs}}).  The matching baseline section
is replaced with the measured results — except keys covered by the
baseline's "exact" glob patterns, which are deterministic DES-model
outputs owned by scripts/fig8_model_baseline.py and are left untouched
(run that script to regenerate them after a model change).

Run this on the reference host class the CI gate uses (wall times are
machine-dependent): the `bench-baseline` workflow_dispatch job in
.github/workflows/ci.yml does exactly that and uploads the refreshed file
as an artifact to commit.
"""

import argparse
import fnmatch
import json
import sys


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("inputs", nargs="+", help="per-bench JSON files")
    ap.add_argument("--baseline", required=True)
    args = ap.parse_args()

    with open(args.baseline) as f:
        baseline = json.load(f)
    exact = baseline.get("exact") or {}

    for path in args.inputs:
        with open(path) as f:
            doc = json.load(f)
        bench = doc["bench"]
        results = doc.get("results", {})
        pats = exact.get(bench, [])
        section = dict(baseline.get(bench) or {})
        kept = 0
        for key, secs in results.items():
            if any(fnmatch.fnmatch(key, p) for p in pats):
                kept += 1  # deterministic row: owned by its generator
                continue
            section[key] = secs
        baseline[bench] = section
        print(f"[refresh-baseline] {bench}: merged {len(results) - kept} "
              f"wall-time keys ({kept} exact keys left to their generator)")

    with open(args.baseline, "w") as f:
        json.dump(baseline, f, indent=1, sort_keys=False)
        f.write("\n")
    print(f"[refresh-baseline] wrote {args.baseline}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
