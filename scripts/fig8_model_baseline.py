#!/usr/bin/env python3
"""Generate the deterministic fig8_fft `model_*` baseline rows.

The fig8 DES model rows (`cargo bench --bench fig8_fft -- --json ...`,
keys `model_<nodes>n<pernode>_<method>`) are *simulated* seconds computed
by pure arithmetic in `rust/src/distfft/mod.rs` over the constants of
`MachineConfig::default()` — they are host-independent and fully
deterministic, so the bench-regression gate holds them at 0% tolerance
(see the "exact" patterns in BENCH_baseline.json; the comparison allows a
1e-9 relative epsilon for libm last-ulp and JSON round-trip noise).

This script is a line-for-line port of that arithmetic (identical
operation order, so IEEE-754 doubles reproduce the Rust values up to libm
last-ulp differences in log2).  Use it to (re)generate the baseline
section after changing the DES model:

    python3 scripts/fig8_model_baseline.py            # print the section
    python3 scripts/fig8_model_baseline.py --check BENCH_baseline.json

Rust reference: fftmpi_time / heffte_time / utofu_time in
rust/src/distfft/mod.rs, bg_dim_reduction_time in rust/src/tofu/mod.rs,
alltoall_time in rust/src/mpisim/mod.rs, makespan_fifo in
rust/src/simnet/mod.rs, constants in rust/src/config/mod.rs.
"""

import argparse
import json
import math
import sys

# MachineConfig::default() (rust/src/config/mod.rs)
CORES_PER_NODE = 48
RANKS_PER_NODE = 4
BG_HOP_LATENCY = 0.25e-6
BG_PAYLOAD_I32 = 12
CHAINS_PER_TNI = 12
TNIS_PER_DIM = 2
P2P_LATENCY = 1.0e-6
LINK_BANDWIDTH = 6.8e9
NODE_FLOPS = 6.0e11

BYTES_PER_VALUE = 16  # complex f64

# paper_topologies() (rust/src/config/mod.rs)
TOPOLOGIES = [
    (12, (2, 3, 2)),
    (96, (4, 6, 4)),
    (768, (8, 12, 8)),
    (1500, (12, 15, 12)),
    (4608, (16, 18, 16)),
    (8400, (20, 21, 20)),
]


def ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def fft1d_flops(n: int) -> float:
    # rust: 5.0 * n as f64 * (n as f64).log2().max(1.0)
    return 5.0 * float(n) * max(math.log2(float(n)), 1.0)


def fft_compute_time(grid, workers: int) -> float:
    gx, gy, gz = grid
    lines = (
        (gy * gz) * fft1d_flops(gx)
        + (gx * gz) * fft1d_flops(gy)
        + (gx * gy) * fft1d_flops(gz)
    )
    core_flops = NODE_FLOPS / float(CORES_PER_NODE)
    return 4.0 * lines / core_flops / float(workers)


def alltoall_time(p: int, bytes_per_pair: int) -> float:
    if p <= 1:
        return 0.0
    return float(p - 1) * (P2P_LATENCY + float(bytes_per_pair) / LINK_BANDWIDTH)


def fftmpi_time(grid, dims, all_ranks: bool):
    nodes = dims[0] * dims[1] * dims[2]
    ranks = nodes * RANKS_PER_NODE if all_ranks else nodes
    total_points = grid[0] * grid[1] * grid[2]
    local_bytes = ceil_div(total_points, ranks) * BYTES_PER_VALUE
    group = int(math.ceil(math.sqrt(float(ranks))))
    remap = alltoall_time(group, ceil_div(local_bytes, max(group, 1)))
    comm = remap + 4.0 * 2.0 * remap
    compute = fft_compute_time(grid, ranks)
    return compute, comm


def heffte_time(grid, dims, all_ranks: bool):
    nodes = dims[0] * dims[1] * dims[2]
    ranks = nodes * RANKS_PER_NODE if all_ranks else nodes
    total_points = grid[0] * grid[1] * grid[2]
    if total_points // ranks < 4:
        return None
    compute, comm = fftmpi_time(grid, dims, all_ranks)
    overhead_per_exchange = 9.0 * P2P_LATENCY
    exchanges = 1.0 + 8.0
    return compute * 1.15, comm * 1.35 + exchanges * overhead_per_exchange


def bg_dim_reduction_time(n: int, values_per_node: int) -> float:
    if n <= 1:
        return 0.0
    per_red = float(n + 1) * BG_HOP_LATENCY
    nred = ceil_div(values_per_node, BG_PAYLOAD_I32)
    slots = CHAINS_PER_TNI * TNIS_PER_DIM
    eff_slots = min(slots, n * max(slots // n, 1))
    jobs = n * nred
    # makespan_fifo over equal-duration jobs: the busiest slot accumulates
    # per_red ceil(jobs / active_slots) times (replicate the repeated FP
    # addition of the rust heap, not a single multiply)
    active = min(max(eff_slots, 1), jobs)
    rounds = ceil_div(jobs, active)
    t = 0.0
    for _ in range(rounds):
        t += per_red
    return t


def utofu_time(grid, dims):
    core_flops = NODE_FLOPS / float(CORES_PER_NODE)
    g = [ceil_div(grid[d], dims[d]) for d in range(3)]
    compute = 0.0
    comm = 0.0
    for d in range(3):
        n_d = dims[d]
        nn = grid[d]
        lines = float(g[(d + 1) % 3] * g[(d + 2) % 3])
        matvec_flops = lines * float(nn) * float(g[d]) * 8.0
        compute += 4.0 * matvec_flops / core_flops
        values = 2 * g[0] * g[1] * g[2]
        comm += 4.0 * bg_dim_reduction_time(n_d, values)
    return compute, comm


def model_rows() -> dict:
    rows = {}
    iters = 1000.0
    for per_node in (4, 5, 6):
        for nodes, dims in TOPOLOGIES:
            grid = (dims[0] * per_node, dims[1] * per_node, dims[2] * per_node)
            key = f"model_{nodes}n{per_node}"
            c, m = fftmpi_time(grid, dims, True)
            rows[f"{key}_fftmpi_all"] = iters * (c + m)
            h = heffte_time(grid, dims, True)
            if h is not None:
                rows[f"{key}_heffte_all"] = iters * (h[0] + h[1])
            h = heffte_time(grid, dims, False)
            if h is not None:
                rows[f"{key}_heffte_master"] = iters * (h[0] + h[1])
            c, m = utofu_time(grid, dims)
            rows[f"{key}_utofu_master"] = iters * (c + m)
    return rows


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--check", metavar="BASELINE",
                    help="verify the fig8_fft model_* rows of BASELINE "
                         "match this script (1e-9 relative)")
    args = ap.parse_args()
    rows = model_rows()
    if args.check:
        with open(args.check) as f:
            base = json.load(f)
        section = base.get("fig8_fft") or {}
        bad = []
        for k, v in rows.items():
            ref = section.get(k)
            if ref is None:
                bad.append(f"{k}: missing from baseline")
            elif abs(ref - v) > 1e-9 * max(abs(v), 1e-300):
                bad.append(f"{k}: baseline {ref!r} vs model {v!r}")
        if bad:
            print("[fig8-model] baseline out of date:", file=sys.stderr)
            for line in bad:
                print(f"  {line}", file=sys.stderr)
            return 1
        print(f"[fig8-model] {len(rows)} rows match the baseline")
        return 0
    print(json.dumps(rows, indent=1, sort_keys=True))
    return 0


if __name__ == "__main__":
    sys.exit(main())
