#!/usr/bin/env python3
"""Generate the deterministic hotpath `model_mts_speedup_k*` baseline keys.

The hotpath bench (`cargo bench --bench hotpath -- --json ...`) records,
next to its wall-clock `mts_k{1,2,4}` keys, the *model-predicted* MTS
speedup ceiling `model_mts_speedup_k{2,4}`: pure arithmetic over
`CostTable::default()` in `rust/src/perfmodel/mod.rs` (fn
`mts_model_speedup`), host-independent and fully deterministic, so the
bench-regression gate holds those keys at 0% tolerance (the comparison
allows a 1e-9 relative epsilon for libm last-ulp and JSON round-trip
noise).  The same keys appear in both the `hotpath` and `hotpath_simd`
baseline sections — the model does not depend on the build features.

This script is a line-for-line port of that arithmetic (identical
operation order, so IEEE-754 doubles reproduce the Rust values up to
libm last-ulp differences in log2):

    python3 scripts/mts_model_baseline.py            # print the keys
    python3 scripts/mts_model_baseline.py --check BENCH_baseline.json

Rust reference: mts_model_speedup + CostTable::default() in
rust/src/perfmodel/mod.rs, core flops from MachineConfig::default() in
rust/src/config/mod.rs.
"""

import argparse
import json
import math
import sys

# CostTable::default() (rust/src/perfmodel/mod.rs)
DP_PER_ATOM = 1.9e-3
DW_FWD_PER_MOL = 0.35e-3
DW_BWD_PER_MOL = 0.45e-3
FP32_SPEEDUP = 1.45
SPREAD_GATHER_PER_SITE = 2.0e-6


def mts_model_speedup(k: int) -> float:
    k = float(max(k, 1))
    # headline per-node load (51 ns/day anchor): 47 atoms on 47 usable
    # cores with node-level task division and fp32 inference
    atoms = 47.0
    mols = atoms / 3.0
    cores = 47.0
    t_sr = (
        (atoms * DP_PER_ATOM + mols * (DW_FWD_PER_MOL + DW_BWD_PER_MOL))
        / FP32_SPEEDUP
        / cores
    )
    # k-space: spread/gather per charged site (ions + WCs) plus the 4
    # FFTs of the 8x12x8 = 768-point headline mesh on one core
    # (MachineConfig::default() node flops over its 48 cores)
    sites = atoms + mols
    n = 768.0
    fft_flops = 4.0 * 5.0 * n * math.log2(n)
    core_flops = 6.0e11 / 48.0
    t_k = sites * SPREAD_GATHER_PER_SITE + fft_flops / core_flops
    return (t_sr + t_k) / (t_sr + t_k / k)


def model_keys() -> dict:
    return {f"model_mts_speedup_k{k}": mts_model_speedup(k) for k in (2, 4)}


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--check", metavar="BASELINE",
                    help="verify the hotpath/hotpath_simd model_mts_* keys "
                         "of BASELINE match this script (1e-9 relative)")
    args = ap.parse_args()
    keys = model_keys()
    if args.check:
        with open(args.check) as f:
            base = json.load(f)
        bad = []
        for section in ("hotpath", "hotpath_simd"):
            rows = base.get(section) or {}
            for k, v in keys.items():
                ref = rows.get(k)
                if ref is None:
                    bad.append(f"{section}.{k}: missing from baseline")
                elif abs(ref - v) > 1e-9 * max(abs(v), 1e-300):
                    bad.append(f"{section}.{k}: baseline {ref!r} vs model {v!r}")
        if bad:
            print("[mts-model] baseline out of date:", file=sys.stderr)
            for line in bad:
                print(f"  {line}", file=sys.stderr)
            return 1
        print(f"[mts-model] {2 * len(keys)} keys match the baseline")
        return 0
    print(json.dumps(keys, indent=1, sort_keys=True))
    return 0


if __name__ == "__main__":
    sys.exit(main())
