"""AOT export: lower the L2 model to HLO **text** artifacts for the rust L3.

HLO text (not `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs (artifacts/):
  dp_ef_{N}_{dt}.hlo.txt   (coords, box, nlist)        -> (E_sr, F_sr)
  dw_fwd_{N}_{dt}.hlo.txt  (coords, box, nlist_o)      -> (delta,)
  dw_vjp_{N}_{dt}.hlo.txt  (coords, box, nlist_o, fwc) -> (delta, f_contrib)
  weights.json             all net parameters (rust native path)
  manifest.json            hyper-parameters + artifact index

Run once via `make artifacts`; python never appears on the request path.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model as M  # noqa: E402
from . import params as P  # noqa: E402

# (nmol, dtypes) per exported system size.  564 atoms = the paper's 188-water
# headline box; 384 = the 128-water accuracy box (Table 1 / Fig 7); 192 = the
# 64-water quickstart box; 12 = smoke size for fast rust unit tests.
SIZES = [
    (4, ["f64"]),
    (64, ["f64"]),
    (128, ["f64", "f32"]),
    (188, ["f64", "f32"]),
]

DTYPES = {"f64": jnp.float64, "f32": jnp.float32}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # The default printer elides large constants as `constant({...})`, which
    # the text parser cannot round-trip — the model weights would be lost.
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # jax 0.8's printer emits source_end_line/... metadata attributes that
    # xla_extension 0.5.1's HLO parser rejects; drop metadata entirely.
    opts.print_metadata = False
    return comp.as_hlo_module().to_string(opts)


def lower_one(fn, arg_specs):
    return to_hlo_text(jax.jit(fn).lower(*arg_specs))


def export(outdir: str, sizes=SIZES, quiet=False):
    os.makedirs(outdir, exist_ok=True)
    prm = P.ModelParams.seeded()
    arts = []
    for nmol, dts in sizes:
        n = 3 * nmol
        s = P.SEL_TOTAL
        for dt in dts:
            jdt = DTYPES[dt]
            coords = jax.ShapeDtypeStruct((n, 3), jdt)
            box = jax.ShapeDtypeStruct((3,), jdt)
            nlist = jax.ShapeDtypeStruct((n, s), jnp.int32)
            nlist_o = jax.ShapeDtypeStruct((nmol, s), jnp.int32)
            fwc = jax.ShapeDtypeStruct((nmol, 3), jdt)
            jobs = [
                ("dp_ef", M.build_dp_ef(nmol, prm), (coords, box, nlist)),
                ("dw_fwd", M.build_dw_fwd(nmol, prm), (coords, box, nlist_o)),
                ("dw_vjp", M.build_dw_vjp(nmol, prm), (coords, box, nlist_o, fwc)),
            ]
            for kind, fn, specs in jobs:
                name = f"{kind}_{n}_{dt}"
                t0 = time.time()
                text = lower_one(fn, specs)
                path = os.path.join(outdir, name + ".hlo.txt")
                with open(path, "w") as f:
                    f.write(text)
                if not quiet:
                    print(
                        f"  {name}: {len(text) / 1e6:.2f} MB "
                        f"({time.time() - t0:.1f}s)"
                    )
                arts.append(
                    {
                        "name": name,
                        "file": name + ".hlo.txt",
                        "kind": kind,
                        "natoms": n,
                        "nmol": nmol,
                        "dtype": dt,
                        "sel_total": s,
                    }
                )
    P.dump_weights(prm, os.path.join(outdir, "weights.json"))
    manifest = {"hyper": P.hyper_dict(), "artifacts": arts}
    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if not quiet:
        print(f"wrote {len(arts)} artifacts + weights.json + manifest.json")


def export_fixtures(outdir: str):
    """Golden numeric fixtures for the rust<->python parity tests.

    For a couple of seeded water systems, dump coords/nlists plus the
    reference-model outputs (E, F, delta, f_contrib) so the rust native and
    PJRT paths can be checked against the exact python numbers.
    """
    import numpy as np

    from . import testutil as TU
    from .kernels import ref

    prm = P.ModelParams.seeded()
    cases = []
    for nmol, seed in [(4, 3), (64, 7), (128, 7)]:
        coords, box = TU.water_box(nmol, seed=seed)
        nl = TU.full_nlist(coords, box, nmol)
        nlo = TU.o_nlist(coords, box, nmol)
        c = jnp.asarray(coords)
        b = jnp.asarray(box)
        e, f = jax.jit(M.build_dp_ef(nmol, prm))(c, b, jnp.asarray(nl))
        fwc = np.asarray(
            np.random.RandomState(nmol).standard_normal((nmol, 3)) * 0.5
        )
        delta, fc = jax.jit(M.build_dw_vjp(nmol, prm))(
            c, b, jnp.asarray(nlo), jnp.asarray(fwc)
        )
        cases.append(
            {
                "nmol": nmol,
                "box": box.tolist(),
                "coords": np.asarray(coords).reshape(-1).tolist(),
                "nlist": np.asarray(nl).reshape(-1).tolist(),
                "nlist_o": np.asarray(nlo).reshape(-1).tolist(),
                "f_wc": fwc.reshape(-1).tolist(),
                "energy": float(e),
                "forces": np.asarray(f).reshape(-1).tolist(),
                "delta": np.asarray(delta).reshape(-1).tolist(),
                "f_contrib": np.asarray(fc).reshape(-1).tolist(),
            }
        )
    with open(os.path.join(outdir, "fixtures.json"), "w") as f:
        json.dump({"cases": cases}, f)
    print(f"wrote fixtures.json ({len(cases)} cases)")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--smoke-only",
        action="store_true",
        help="export only the 12-atom smoke artifacts (fast CI path)",
    )
    args = ap.parse_args()
    sizes = [SIZES[0]] if args.smoke_only else SIZES
    export(args.out, sizes)
    export_fixtures(args.out)


if __name__ == "__main__":
    main()
