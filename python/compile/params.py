"""Model hyper-parameters and seeded weight initialisation for DP / DW nets.

The paper's models (DeepPot-SE short-range "DP" + Deep Wannier "DW") use a
fitting net of (240, 240, 240) [paper §4] and sel = (46, 92) neighbours for
O / H at a 6 Angstrom cutoff.  We keep those numbers (padded to multiples of
8 for TPU-friendly tiling) and choose a compact embedding net so the whole
stack traces quickly under Pallas interpret mode.

Weights are *seeded*, not trained: there is no network access to the paper's
Zenodo dataset in this environment (see DESIGN.md section 2).  The physical
prior in model.py keeps the dynamics stable; the NN contributes genuinely
nonzero (but small) energies/forces so every code path is exercised with
realistic tensor shapes.

All weights are exported to artifacts/weights.json so that the Rust
framework-free inference path (rust/src/native/) can reproduce the PJRT
results bit-for-bit (modulo float summation order).
"""

from __future__ import annotations

import dataclasses
import json

import numpy as np

# ----------------------------------------------------------------------------
# Hyper-parameters (shared by python and rust through manifest.json)
# ----------------------------------------------------------------------------

R_CUT = 6.0  # outer cutoff [A] (paper section 4)
R_CUT_SMOOTH = 3.0  # inner smooth-switch start [A]
SEL = (48, 96)  # padded max neighbours per type (O, H); paper uses 46/92
SEL_TOTAL = SEL[0] + SEL[1]
EMBED_WIDTHS = (24, 48)  # embedding net widths; last = M1
M1 = EMBED_WIDTHS[-1]
M2 = 8  # axis neurons: first M2 columns of G form G<
FIT_WIDTHS = (240, 240, 240)  # fitting net widths (paper section 4)
DESC_DIM = M1 * M2

# DPLR charges for water: O ion +6 e, H ion +1 e, Wannier centroid -8 e
# (8 valence electrons per molecule collapse to one WC bound to the O).
Q_O = 6.0
Q_H = 1.0
Q_WC = -8.0

# Ewald / PPPM smearing: exp(-k^2 / (4 alpha^2)) Gaussian screening [1/A].
ALPHA = 1.0

# Physical prior (keeps seeded-weight dynamics stable and water-like):
# harmonic intramolecular bonds + angle, Born-Mayer intermolecular repulsion.
BOND_K = 18.0  # eV / A^2
BOND_R0 = 0.9572  # A
ANGLE_K = 2.5  # eV / rad^2
ANGLE_T0 = 1.8242  # rad (104.52 deg)
BM_A = {("O", "O"): 450.0, ("O", "H"): 80.0, ("H", "H"): 20.0}  # eV
BM_RHO = 0.35  # A
NN_ENERGY_SCALE = 0.02  # eV per atom scale of the seeded NN contribution
# Radial clamp on the predicted WC displacement [A].  Keeps the molecular
# dipole |q_wc| * |delta| <= 0.4 e*A, i.e. water-like (~1.9 D); the seeded
# (untrained) DW net would otherwise predict ~10 D molecules and the
# electrostatics would dominate the dynamics unphysically.
WC_CLAMP = 0.05

MASS_O = 15.9994  # g/mol
MASS_H = 1.008

# LAMMPS "metal"-like units: eV, A, ps; Coulomb constant in eV*A/e^2.
KE_COULOMB = 14.399645478425668
# Boltzmann constant in eV/K.
KB_EV = 8.617333262e-5


@dataclasses.dataclass
class Mlp:
    """Dense tanh MLP parameters: y = tanh(x W + b) per layer, linear last."""

    weights: list  # list of np.ndarray (in, out)
    biases: list  # list of np.ndarray (out,)

    def tolists(self):
        return {
            "weights": [w.tolist() for w in self.weights],
            "biases": [b.tolist() for b in self.biases],
        }


def _init_mlp(rng: np.random.RandomState, widths, din, dout, out_scale=1.0):
    ws, bs = [], []
    prev = din
    for w in widths:
        ws.append(rng.standard_normal((prev, w)) / np.sqrt(prev))
        bs.append(rng.standard_normal(w) * 0.1)
        prev = w
    ws.append(rng.standard_normal((prev, dout)) / np.sqrt(prev) * out_scale)
    bs.append(np.zeros(dout))
    return Mlp(ws, bs)


@dataclasses.dataclass
class ModelParams:
    """All learnable parameters of the DP + DW models.

    embed_dp / embed_dw: one embedding MLP per *neighbour* type (O, H),
    input = the scaled radial feature s(r), output width M1.
    fit_dp: one fitting MLP per *centre* type (O, H), desc -> atomic energy.
    fit_dw: fitting MLP for O centres, desc -> M1 gating vector used to form
    the rotation-covariant Wannier displacement.
    """

    embed_dp: list  # [Mlp; 2]
    fit_dp: list  # [Mlp; 2]
    embed_dw: list  # [Mlp; 2]
    fit_dw: Mlp

    @staticmethod
    def seeded(seed: int = 20250710) -> "ModelParams":
        rng = np.random.RandomState(seed)
        embed_dp = [_init_mlp(rng, EMBED_WIDTHS[:-1], 1, M1) for _ in range(2)]
        fit_dp = [
            _init_mlp(rng, FIT_WIDTHS, DESC_DIM, 1, out_scale=NN_ENERGY_SCALE)
            for _ in range(2)
        ]
        embed_dw = [_init_mlp(rng, EMBED_WIDTHS[:-1], 1, M1) for _ in range(2)]
        fit_dw = _init_mlp(rng, FIT_WIDTHS, DESC_DIM, M1, out_scale=0.3)
        return ModelParams(embed_dp, fit_dp, embed_dw, fit_dw)

    def tolists(self):
        return {
            "embed_dp": [m.tolists() for m in self.embed_dp],
            "fit_dp": [m.tolists() for m in self.fit_dp],
            "embed_dw": [m.tolists() for m in self.embed_dw],
            "fit_dw": self.fit_dw.tolists(),
        }


def hyper_dict():
    """Hyper-parameters shared with rust via manifest.json."""
    return {
        "r_cut": R_CUT,
        "r_cut_smooth": R_CUT_SMOOTH,
        "sel": list(SEL),
        "embed_widths": list(EMBED_WIDTHS),
        "m1": M1,
        "m2": M2,
        "fit_widths": list(FIT_WIDTHS),
        "desc_dim": DESC_DIM,
        "q_o": Q_O,
        "q_h": Q_H,
        "q_wc": Q_WC,
        "alpha": ALPHA,
        "bond_k": BOND_K,
        "bond_r0": BOND_R0,
        "angle_k": ANGLE_K,
        "angle_t0": ANGLE_T0,
        "bm_a_oo": BM_A[("O", "O")],
        "bm_a_oh": BM_A[("O", "H")],
        "bm_a_hh": BM_A[("H", "H")],
        "bm_rho": BM_RHO,
        "nn_energy_scale": NN_ENERGY_SCALE,
        "wc_clamp": WC_CLAMP,
        "mass_o": MASS_O,
        "mass_h": MASS_H,
        "ke_coulomb": KE_COULOMB,
        "kb_ev": KB_EV,
    }


def dump_weights(params: ModelParams, path: str):
    with open(path, "w") as f:
        json.dump(params.tolists(), f)
