"""Test helpers: build water boxes and padded neighbour lists in numpy.

Mirrors rust/src/md/water.rs and rust/src/neighbor/ — the python tests use
these to generate realistic inputs; the cross-language integration tests
(rust side) check both implementations agree on the same seeds.
"""

from __future__ import annotations

import numpy as np

from . import params as P


def water_box(nmol: int, seed: int = 7, jitter: float = 0.05):
    """nmol rigid-ish water molecules on a jittered cubic lattice.

    Returns (coords (3*nmol, 3) f64, box (3,) f64).  Density ~= 1 g/cc
    (29.9 A^3 per molecule).  Atom order: O block then H pairs.
    """
    rng = np.random.RandomState(seed)
    vol = 29.9 * nmol
    edge = vol ** (1.0 / 3.0)
    ncell = int(np.ceil(nmol ** (1.0 / 3.0)))
    a = edge / ncell
    # stride-select nmol of the ncell^3 sites so density stays uniform when
    # nmol is not a perfect cube (mirrors rust/src/md/water.rs)
    nsites = ncell ** 3
    picked = [(c * nsites) // nmol for c in range(nmol)]
    sites = np.array(
        [
            (s // (ncell * ncell), (s % (ncell * ncell)) // ncell, s % ncell)
            for s in picked
        ],
        dtype=np.float64,
    )
    o = (sites + 0.5) * a + rng.uniform(-jitter, jitter, (nmol, 3))
    # random molecular orientation, ~gas-phase geometry
    r0, theta = P.BOND_R0, P.ANGLE_T0
    coords = np.zeros((3 * nmol, 3))
    coords[:nmol] = o
    for m in range(nmol):
        axis = rng.standard_normal(3)
        axis /= np.linalg.norm(axis)
        # build an orthonormal frame around `axis`
        ref = np.array([1.0, 0.0, 0.0])
        if abs(axis @ ref) > 0.9:
            ref = np.array([0.0, 1.0, 0.0])
        u = np.cross(axis, ref)
        u /= np.linalg.norm(u)
        v = np.cross(axis, u)
        h1 = o[m] + r0 * (np.cos(theta / 2) * axis + np.sin(theta / 2) * u)
        h2 = o[m] + r0 * (np.cos(theta / 2) * axis - np.sin(theta / 2) * u)
        coords[nmol + 2 * m] = h1
        coords[nmol + 2 * m + 1] = h2
    box = np.array([edge, edge, edge])
    return coords % box, box


def build_nlist(coords, box, centres, nmol):
    """Padded typed neighbour list for the given centre indices.

    Columns [0, SEL[0]) = O neighbours (sorted by distance, nearest first),
    [SEL[0], SEL_TOTAL) = H neighbours; -1 padding.  Over-full shells keep
    the nearest SEL[t] neighbours (same policy as the rust builder).
    """
    n = coords.shape[0]
    d = coords[None, :, :] - coords[centres, None, :]
    d -= box * np.round(d / box)
    r = np.linalg.norm(d, axis=-1)
    out = np.full((len(centres), P.SEL_TOTAL), -1, dtype=np.int32)
    for row, i in enumerate(centres):
        for t, (lo, cap) in enumerate(((0, P.SEL[0]), (P.SEL[0], P.SEL[1]))):
            idx = np.arange(nmol) if t == 0 else np.arange(nmol, n)
            rr = r[row, idx]
            sel = idx[(rr < P.R_CUT) & (idx != i)]
            sel = sel[np.argsort(r[row, sel])][:cap]
            out[row, lo : lo + len(sel)] = sel
    return out


def full_nlist(coords, box, nmol):
    return build_nlist(coords, box, np.arange(coords.shape[0]), nmol)


def o_nlist(coords, box, nmol):
    return build_nlist(coords, box, np.arange(nmol), nmol)
