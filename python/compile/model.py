"""Layer-2 JAX model: DPLR energies/forces assembled from the L1 kernels.

Three exported computations (per system size and dtype), mirroring the three
NN stages of a DPLR time step (paper Fig. 1 and section 3.2):

  dp_ef   (coords, box, nlist)        -> (E_sr, F_sr)
  dw_fwd  (coords, box, nlist_o)      -> (delta,)
  dw_vjp  (coords, box, nlist_o, fwc) -> (delta, f_contrib)

E_sr = seeded DP network + analytic physical prior (DESIGN.md section 2's
training substitution).  Forces come from jax.grad; the Pallas kernels carry
jax.custom_vjp rules so backprop uses the jnp reference path while the
forward pass runs the fused kernels — the same fwd-kernel/bwd-backprop split
the paper's framework-free code uses.

dw_vjp implements the long-range force chain of Eq. 6: given the PPPM forces
on the Wannier centroids f_wc = -dE_Gt/dW, it pulls them back through
W(R) = R_O + Delta(R), yielding both the direct binding-atom term and the
-sum_n (dE_Gt/dW_n)(dDelta_n/dR_i) term in one VJP.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import params as P
from .kernels import ref
from .kernels.pallas_kernels import (
    embedding_pallas,
    env_mat_pallas,
    fitting_pallas,
)


def descriptor(env, s, embed_mlps):
    """DeepPot-SE descriptor using the Pallas embedding kernel."""
    s0, s1 = s[:, : P.SEL[0]], s[:, P.SEL[0] :]
    g0 = embedding_pallas(s0, embed_mlps[0])
    g1 = embedding_pallas(s1, embed_mlps[1])
    g = jnp.concatenate([g0, g1], axis=1)
    mask = (s > 0).astype(env.dtype)[:, :, None]
    g = g * mask
    t1 = jnp.einsum("nsm,nsf->nmf", g, env) / P.SEL_TOTAL
    t2 = t1[:, : P.M2, :]
    d = jnp.einsum("nmf,naf->nma", t1, t2)
    return d.reshape(d.shape[0], P.DESC_DIM)


def dp_energy(coords, box, nlist, nmol, prm):
    """Short-range energy: Pallas-kernel NN + jnp physical prior."""
    env, s = env_mat_pallas(coords, box, nlist)
    desc = descriptor(env, s, prm.embed_dp)
    e_o = fitting_pallas(desc[:nmol], prm.fit_dp[0])
    e_h = fitting_pallas(desc[nmol:], prm.fit_dp[1])
    e_nn = jnp.sum(e_o) + jnp.sum(e_h)
    return e_nn + ref.prior_energy_ref(coords, box, nlist, nmol)


def dw_delta(coords, box, nlist_o, nmol, prm):
    """Wannier-centroid displacements using the Pallas kernels."""
    env, s = env_mat_pallas(coords, box, nlist_o)
    desc = descriptor(env, s, prm.embed_dw)
    a = fitting_pallas(desc, prm.fit_dw)
    s0, s1 = s[:, : P.SEL[0]], s[:, P.SEL[0] :]
    g = jnp.concatenate(
        [embedding_pallas(s0, prm.embed_dw[0]), embedding_pallas(s1, prm.embed_dw[1])],
        axis=1,
    )
    gate = jnp.einsum("nsm,nm->ns", g, a) * s
    d, _ = ref.gather_disp(coords, box, nlist_o)
    raw = jnp.einsum("ns,nsf->nf", gate, d)
    norm = jnp.sqrt(jnp.maximum(jnp.sum(raw * raw, axis=-1), 1e-18))
    scale = P.WC_CLAMP * jnp.tanh(norm / P.WC_CLAMP) / norm
    return raw * scale[:, None]


# ----------------------------------------------------------------------------
# builders for the AOT-exported entry points
# ----------------------------------------------------------------------------


def build_dp_ef(nmol, prm):
    """(coords, box, nlist) -> (E_sr, F_sr); forces via backprop (Fig 1c)."""

    def fn(coords, box, nlist):
        e, grad = jax.value_and_grad(
            lambda c: dp_energy(c, box, nlist, nmol, prm)
        )(coords)
        return e, -grad

    return fn


def build_dw_fwd(nmol, prm):
    """(coords, box, nlist_o) -> (delta,); the pre-PPPM DW inference."""

    def fn(coords, box, nlist_o):
        return (dw_delta(coords, box, nlist_o, nmol, prm),)

    return fn


def build_dw_vjp(nmol, prm):
    """(coords, box, nlist_o, f_wc) -> (delta, f_contrib).

    f_contrib[i] = sum_n f_wc[n] . dW_n/dR_i  — the two long-range force
    terms of Eq. 6 (binding-atom term + DW-Jacobian term) in one pullback.
    """

    def fn(coords, box, nlist_o, f_wc):
        def wfn(c):
            return c[:nmol] + dw_delta(c, box, nlist_o, nmol, prm)

        w, pull = jax.vjp(wfn, coords)
        delta = w - coords[:nmol]
        return delta, pull(f_wc)[0]

    return fn
