"""L1: Pallas kernels for the DPLR compute hot-spots + pure-jnp oracle.

`ref` is the correctness oracle (and the source of all custom_vjp backward
passes); `pallas_kernels` holds the fused forward kernels.
"""

from . import ref  # noqa: F401
from . import pallas_kernels  # noqa: F401
