"""Layer-1 Pallas kernels for the DPLR compute hot-spots.

Three kernels cover the per-step inner loops the paper hand-optimizes in
section 3.4.2 (framework-free fused kernels on A64FX):

  * env_mat   — switch function + environment-matrix rows, fused elementwise
                (VPU-shaped work);
  * embedding — the per-(atom, neighbour) embedding MLP, the dominant matmul
                volume (MXU-shaped: rows = atom*neighbour tile);
  * fitting   — the (240, 240, 240) ResNet fitting MLP, fused as one kernel
                so the activations never leave VMEM.

Hardware adaptation (see DESIGN.md section 3): the paper tiles for A64FX SVE
lanes and L2; here BlockSpec tiles rows into VMEM-resident blocks whose
widths are padded to lane multiples, and each block's whole layer stack runs
inside one kernel body — the Pallas/TPU expression of the same fusion.

All kernels run under interpret=True (the CPU PJRT plugin cannot execute
Mosaic custom-calls); they lower to plain HLO inside the same artifact as the
surrounding jnp code.  Gradients: jax.custom_vjp with forward = the kernel
and backward = jax.vjp over the pure-jnp reference (kernels/ref.py), so
force-backprop never differentiates through pallas_call.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Rows per VMEM block.  256 rows x 240 features x 4B = 245 KiB << 16 MiB
# VMEM; the grid walks atom*neighbour tiles HBM->VMEM (BlockSpec schedule).
BLOCK_ROWS = 256


def _pad_rows(x, block):
    r = x.shape[0]
    pad = (-r) % block
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], 0)
    return x, r


# ----------------------------------------------------------------------------
# env_mat kernel
# ----------------------------------------------------------------------------


def _env_kernel(d_ref, m_ref, o_ref, *, rcs, rc):
    d = d_ref[...]
    mask = m_ref[...]
    r2 = jnp.sum(d * d, axis=-1, keepdims=True)
    r = jnp.sqrt(jnp.maximum(r2, 1e-12))
    uu = jnp.clip((r - rcs) / (rc - rcs), 0.0, 1.0)
    sw = uu * uu * uu * (-6.0 * uu * uu + 15.0 * uu - 10.0) + 1.0
    live = mask > 0
    s = jnp.where(live, sw / r, 0.0)
    unit = jnp.where(live, d / r, 0.0)
    o_ref[...] = jnp.concatenate([s, s * unit], axis=-1)


def _env_rows_fwd(d, mask):
    from .. import params as P

    (dp, rows) = _pad_rows(d, BLOCK_ROWS)
    (mp, _) = _pad_rows(mask[:, None], BLOCK_ROWS)
    grid = dp.shape[0] // BLOCK_ROWS
    out = pl.pallas_call(
        functools.partial(_env_kernel, rcs=P.R_CUT_SMOOTH, rc=P.R_CUT),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, 3), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_ROWS, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, 4), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((dp.shape[0], 4), d.dtype),
        interpret=True,
    )(dp, mp)
    return out[:rows]


@jax.custom_vjp
def env_rows(d, mask):
    """Pallas env-matrix rows; numerically identical to ref.env_rows_ref."""
    return _env_rows_fwd(d, mask)


def _env_vjp_fwd(d, mask):
    return _env_rows_fwd(d, mask), (d, mask)


def _env_vjp_bwd(res, g):
    d, mask = res
    _, pull = jax.vjp(lambda dd: ref.env_rows_ref(dd, mask), d)
    return (pull(g)[0], None)


env_rows.defvjp(_env_vjp_fwd, _env_vjp_bwd)


# ----------------------------------------------------------------------------
# embedding kernel: fused (1 -> w1 tanh -> M1 linear) over row blocks
# ----------------------------------------------------------------------------


def _embed_kernel(s_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    s = s_ref[...]
    h = jnp.tanh(s @ w1_ref[...] + b1_ref[...])
    o_ref[...] = h @ w2_ref[...] + b2_ref[...]


def _embed_fwd(s_flat, w1, b1, w2, b2):
    (sp, rows) = _pad_rows(s_flat[:, None], BLOCK_ROWS)
    grid = sp.shape[0] // BLOCK_ROWS
    h1, m1 = w1.shape[1], w2.shape[1]
    out = pl.pallas_call(
        _embed_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, h1), lambda i: (0, 0)),
            pl.BlockSpec((h1,), lambda i: (0,)),
            pl.BlockSpec((h1, m1), lambda i: (0, 0)),
            pl.BlockSpec((m1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, m1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((sp.shape[0], m1), s_flat.dtype),
        interpret=True,
    )(sp, w1, b1, w2, b2)
    return out[:rows]


@jax.custom_vjp
def embedding_rows(s_flat, w1, b1, w2, b2):
    """Pallas fused embedding MLP over flattened (atom*neighbour) rows."""
    return _embed_fwd(s_flat, w1, b1, w2, b2)


def _embed_vjp_fwd(s_flat, w1, b1, w2, b2):
    return _embed_fwd(s_flat, w1, b1, w2, b2), (s_flat, w1, b1, w2, b2)


def _embed_vjp_bwd(res, g):
    s_flat, w1, b1, w2, b2 = res

    def f(ss):
        h = jnp.tanh(ss[:, None] @ w1 + b1)
        return h @ w2 + b2

    _, pull = jax.vjp(f, s_flat)
    return (pull(g)[0], None, None, None, None)


embedding_rows.defvjp(_embed_vjp_fwd, _embed_vjp_bwd)


# ----------------------------------------------------------------------------
# fitting kernel: fused tanh -> (tanh+skip) x 2 -> linear
# ----------------------------------------------------------------------------


def _fit_kernel(x_ref, w1, b1, w2, b2, w3, b3, w4, b4, o_ref):
    x = x_ref[...]
    h = jnp.tanh(x @ w1[...] + b1[...])
    h = h + jnp.tanh(h @ w2[...] + b2[...])
    h = h + jnp.tanh(h @ w3[...] + b3[...])
    o_ref[...] = h @ w4[...] + b4[...]


def _fit_fwd(desc, ws, bs):
    (dp, rows) = _pad_rows(desc, BLOCK_ROWS)
    grid = dp.shape[0] // BLOCK_ROWS
    din = desc.shape[1]
    dims = [w.shape for w in ws]
    dout = dims[-1][1]
    specs = [pl.BlockSpec((BLOCK_ROWS, din), lambda i: (i, 0))]
    args = [dp]
    for w, b in zip(ws, bs):
        specs.append(pl.BlockSpec(w.shape, lambda i: (0, 0)))
        specs.append(pl.BlockSpec(b.shape, lambda i: (0,)))
        args.extend([w, b])
    out = pl.pallas_call(
        _fit_kernel,
        grid=(grid,),
        in_specs=specs,
        out_specs=pl.BlockSpec((BLOCK_ROWS, dout), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((dp.shape[0], dout), desc.dtype),
        interpret=True,
    )(*args)
    return out[:rows]


@jax.custom_vjp
def fitting_rows(desc, w1, b1, w2, b2, w3, b3, w4, b4):
    """Pallas fused fitting net (3 ResNet tanh layers + linear head)."""
    return _fit_fwd(desc, [w1, w2, w3, w4], [b1, b2, b3, b4])


def _fit_vjp_fwd(desc, w1, b1, w2, b2, w3, b3, w4, b4):
    out = _fit_fwd(desc, [w1, w2, w3, w4], [b1, b2, b3, b4])
    return out, (desc, w1, b1, w2, b2, w3, b3, w4, b4)


def _fit_vjp_bwd(res, g):
    desc, w1, b1, w2, b2, w3, b3, w4, b4 = res

    def f(x):
        h = jnp.tanh(x @ w1 + b1)
        h = h + jnp.tanh(h @ w2 + b2)
        h = h + jnp.tanh(h @ w3 + b3)
        return h @ w4 + b4

    _, pull = jax.vjp(f, desc)
    return (pull(g)[0],) + (None,) * 8


fitting_rows.defvjp(_fit_vjp_fwd, _fit_vjp_bwd)


# ----------------------------------------------------------------------------
# wrappers matching the ref.py call signatures
# ----------------------------------------------------------------------------


def embedding_pallas(s, mlp):
    """(M, S') radial features -> (M, S', M1) via the fused Pallas kernel."""
    dt = s.dtype
    w = [jnp.asarray(a, dt) for a in mlp.weights]
    b = [jnp.asarray(a, dt) for a in mlp.biases]
    flat = embedding_rows(s.reshape(-1), w[0], b[0], w[1], b[1])
    return flat.reshape(s.shape + (w[1].shape[1],))


def fitting_pallas(desc, mlp):
    dt = desc.dtype
    w = [jnp.asarray(a, dt) for a in mlp.weights]
    b = [jnp.asarray(a, dt) for a in mlp.biases]
    return fitting_rows(desc, w[0], b[0], w[1], b[1], w[2], b[2], w[3], b[3])


def env_mat_pallas(coords, box, nlist):
    """(M, S, 4) environment matrix + (M, S) radial feature, Pallas fwd."""
    d, mask = ref.gather_disp(coords, box, nlist)
    mm, ss = nlist.shape
    rows = env_rows(d.reshape(-1, 3), mask.reshape(-1))
    env = rows.reshape(mm, ss, 4)
    return env, env[:, :, 0]
