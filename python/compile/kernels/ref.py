"""Pure-jnp reference oracle for every Pallas kernel and for the full models.

Everything here is straight jax.numpy with no Pallas: it defines the ground
truth the kernels are tested against (python/tests/) and it also provides the
backward passes for the kernels' jax.custom_vjp rules (we never differentiate
*through* a pallas_call; forward = kernel, backward = jax.vjp of these
reference functions, lowered into the same HLO artifact).

Conventions (shared with rust/src/native and rust/src/md):
  * atoms are type-sorted: indices [0, nmol) are O, [nmol, 3*nmol) are H
    (molecule m owns O = m, H1 = nmol + 2m, H2 = nmol + 2m + 1);
  * the neighbour list is padded per type: columns [0, SEL[0]) hold O
    neighbours, columns [SEL[0], SEL_TOTAL) hold H neighbours, -1 = empty;
  * boxes are orthorhombic, passed as the three edge lengths;
  * displacements use the minimum-image convention (box edge >= 2 * r_cut).
"""

from __future__ import annotations

import jax.numpy as jnp

from .. import params as P


# ----------------------------------------------------------------------------
# geometry
# ----------------------------------------------------------------------------

def min_image(d, box):
    """Minimum-image displacement for an orthorhombic box."""
    return d - box * jnp.round(d / box)


def gather_disp(coords, box, nlist):
    """Displacements centre->neighbour and validity mask.

    coords: (N, 3); nlist: (M, S) int32 (-1 pad).
    Returns d: (M, S, 3), mask: (M, S) in {0, 1} (same dtype as coords).
    """
    m = nlist >= 0
    safe = jnp.where(m, nlist, 0)
    centres = coords[: nlist.shape[0]]
    d = coords[safe] - centres[:, None, :]
    d = min_image(d, box)
    mask = m.astype(coords.dtype)
    return d * mask[:, :, None], mask


# ----------------------------------------------------------------------------
# switch function and environment matrix (kernel: env_mat)
# ----------------------------------------------------------------------------

def switch_poly(r):
    """DeepPot-SE smooth switch: 1 below rcs, C2 polynomial decay to 0 at rc."""
    rcs, rc = P.R_CUT_SMOOTH, P.R_CUT
    uu = (r - rcs) / (rc - rcs)
    uu = jnp.clip(uu, 0.0, 1.0)
    return uu * uu * uu * (-6.0 * uu * uu + 15.0 * uu - 10.0) + 1.0


def env_rows_ref(d, mask):
    """Rowwise environment matrix: (R, 3) disp + (R,) mask -> (R, 4).

    Row = (s, s x/r, s y/r, s z/r) with s = switch(r) / r, zero where masked.
    This is the flattened form the Pallas kernel computes.
    """
    r2 = jnp.sum(d * d, axis=-1)
    r = jnp.sqrt(jnp.maximum(r2, 1e-12))
    sw = switch_poly(r)
    s = jnp.where(mask > 0, sw / r, 0.0)
    unit = jnp.where(mask[:, None] > 0, d / r[:, None], 0.0)
    return jnp.concatenate([s[:, None], s[:, None] * unit], axis=-1)


def env_mat_ref(coords, box, nlist):
    """(M, S, 4) environment matrix + (M, S) radial feature s."""
    d, mask = gather_disp(coords, box, nlist)
    mm, ss = nlist.shape
    rows = env_rows_ref(d.reshape(-1, 3), mask.reshape(-1))
    env = rows.reshape(mm, ss, 4)
    return env, env[:, :, 0]


# ----------------------------------------------------------------------------
# MLPs (kernels: embedding, fitting)
# ----------------------------------------------------------------------------

def apply_mlp_ref(x, weights, biases):
    """tanh layers (with ResNet skip when square) + linear final layer."""
    for w, b in zip(weights[:-1], biases[:-1]):
        y = jnp.tanh(x @ w + b)
        x = x + y if w.shape[0] == w.shape[1] else y
    return x @ weights[-1] + biases[-1]


def embedding_ref(s, mlp):
    """Per-neighbour embedding: (..., scalar feature) -> (..., M1)."""
    w = [jnp.asarray(a, dtype=s.dtype) for a in mlp.weights]
    b = [jnp.asarray(a, dtype=s.dtype) for a in mlp.biases]
    return apply_mlp_ref(s[..., None], w, b)


def fitting_ref(desc, mlp):
    w = [jnp.asarray(a, dtype=desc.dtype) for a in mlp.weights]
    b = [jnp.asarray(a, dtype=desc.dtype) for a in mlp.biases]
    return apply_mlp_ref(desc, w, b)


# ----------------------------------------------------------------------------
# descriptor
# ----------------------------------------------------------------------------

def descriptor_ref(env, s, embed_mlps):
    """DeepPot-SE descriptor D = (G^T R)(R^T G<) flattened to (M, M1*M2).

    env: (M, S, 4); s: (M, S).  The first SEL[0] neighbour slots use the O
    embedding net, the rest the H net.
    """
    s0, s1 = s[:, : P.SEL[0]], s[:, P.SEL[0] :]
    g0 = embedding_ref(s0, embed_mlps[0])
    g1 = embedding_ref(s1, embed_mlps[1])
    g = jnp.concatenate([g0, g1], axis=1)  # (M, S, M1)
    # mask embedded rows of padded neighbours (s == 0 does NOT zero the MLP
    # output because of biases): weight by s-presence.
    mask = (s > 0).astype(env.dtype)[:, :, None]
    g = g * mask
    t1 = jnp.einsum("nsm,nsf->nmf", g, env) / P.SEL_TOTAL  # (M, M1, 4)
    t2 = t1[:, : P.M2, :]  # (M, M2, 4)
    d = jnp.einsum("nmf,naf->nma", t1, t2)  # (M, M1, M2)
    return d.reshape(d.shape[0], P.DESC_DIM)


# ----------------------------------------------------------------------------
# DP model: short-range NN energy
# ----------------------------------------------------------------------------

def dp_nn_energy_ref(coords, box, nlist, nmol, prm):
    env, s = env_mat_ref(coords, box, nlist)
    desc = descriptor_ref(env, s, prm.embed_dp)
    e_o = fitting_ref(desc[:nmol], prm.fit_dp[0])
    e_h = fitting_ref(desc[nmol:], prm.fit_dp[1])
    return jnp.sum(e_o) + jnp.sum(e_h)


# ----------------------------------------------------------------------------
# physical prior (bonds + angle + Born-Mayer repulsion)
# ----------------------------------------------------------------------------

def prior_energy_ref(coords, box, nlist, nmol):
    n = 3 * nmol
    o = coords[:nmol]
    h1 = coords[nmol + 0 : n : 2]
    h2 = coords[nmol + 1 : n : 2]
    d1 = min_image(h1 - o, box)
    d2 = min_image(h2 - o, box)
    r1 = jnp.sqrt(jnp.sum(d1 * d1, axis=-1))
    r2 = jnp.sqrt(jnp.sum(d2 * d2, axis=-1))
    e_bond = P.BOND_K * jnp.sum((r1 - P.BOND_R0) ** 2 + (r2 - P.BOND_R0) ** 2)
    cosang = jnp.sum(d1 * d2, axis=-1) / (r1 * r2)
    ang = jnp.arccos(jnp.clip(cosang, -1.0 + 1e-9, 1.0 - 1e-9))
    e_ang = P.ANGLE_K * jnp.sum((ang - P.ANGLE_T0) ** 2)

    # Born-Mayer repulsion over the padded neighbour list (double counts
    # every pair -> factor 1/2), smoothly switched off at the cutoff.
    d, mask = gather_disp(coords, box, nlist)
    r = jnp.sqrt(jnp.maximum(jnp.sum(d * d, axis=-1), 1e-12))
    sw = switch_poly(r)
    # per-pair A: centre type x neighbour type (O block then H block).
    is_h_centre = (jnp.arange(nlist.shape[0]) >= nmol).astype(coords.dtype)
    is_h_nbr = jnp.concatenate(
        [
            jnp.zeros((nlist.shape[0], P.SEL[0]), coords.dtype),
            jnp.ones((nlist.shape[0], P.SEL[1]), coords.dtype),
        ],
        axis=1,
    )
    a_oo = P.BM_A[("O", "O")]
    a_oh = P.BM_A[("O", "H")]
    a_hh = P.BM_A[("H", "H")]
    ch = is_h_centre[:, None]
    amat = (
        a_oo * (1 - ch) * (1 - is_h_nbr)
        + a_oh * (ch * (1 - is_h_nbr) + (1 - ch) * is_h_nbr)
        + a_hh * ch * is_h_nbr
    )
    e_bm = 0.5 * jnp.sum(mask * sw * amat * jnp.exp(-r / P.BM_RHO))
    return e_bond + e_ang + e_bm


def dp_energy_ref(coords, box, nlist, nmol, prm):
    """Full short-range energy: seeded NN + physical prior."""
    return dp_nn_energy_ref(coords, box, nlist, nmol, prm) + prior_energy_ref(
        coords, box, nlist, nmol
    )


# ----------------------------------------------------------------------------
# DW model: rotation-covariant Wannier-centroid displacement
# ----------------------------------------------------------------------------

def dw_delta_ref(coords, box, nlist_o, nmol, prm):
    """Predicted WC displacement for each O atom: (nmol, 3).

    Delta_i = clamp( sum_j c_ij * d_ij ) with invariant per-neighbour gates
    c_ij = s_ij * <G_ij, a_i>, a_i = fit_dw(D_i).  Rotation-covariant because
    only the d_ij vectors carry direction.
    """
    env, s = env_mat_ref(coords, box, nlist_o)
    desc = descriptor_ref(env, s, prm.embed_dw)
    a = fitting_ref(desc, prm.fit_dw)  # (nmol, M1)
    s0, s1 = s[:, : P.SEL[0]], s[:, P.SEL[0] :]
    g = jnp.concatenate(
        [embedding_ref(s0, prm.embed_dw[0]), embedding_ref(s1, prm.embed_dw[1])],
        axis=1,
    )
    gate = jnp.einsum("nsm,nm->ns", g, a) * s  # (nmol, S); s masks padding
    d, _ = gather_disp(coords, box, nlist_o)
    raw = jnp.einsum("ns,nsf->nf", gate, d)
    # radial (covariant) clamp to WC_CLAMP angstroms
    norm = jnp.sqrt(jnp.maximum(jnp.sum(raw * raw, axis=-1), 1e-18))
    scale = P.WC_CLAMP * jnp.tanh(norm / P.WC_CLAMP) / norm
    return raw * scale[:, None]
