"""L1 correctness: every Pallas kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes/dtypes/values; these are the core correctness
signal for the kernels that end up inside the AOT artifacts.
"""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import params as P
from compile.kernels import ref
from compile.kernels.pallas_kernels import (
    BLOCK_ROWS,
    embedding_pallas,
    env_mat_pallas,
    env_rows,
    fitting_pallas,
)

PRM = P.ModelParams.seeded()


def tol(dt):
    return dict(rtol=1e-10, atol=1e-12) if dt == np.float64 else dict(rtol=2e-5, atol=1e-5)


# ----------------------------------------------------------------------------
# env_mat kernel
# ----------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    rows=st.integers(1, 3 * BLOCK_ROWS + 7),
    seed=st.integers(0, 2**31 - 1),
    dt=st.sampled_from([np.float32, np.float64]),
)
def test_env_rows_matches_ref(rows, seed, dt):
    rng = np.random.RandomState(seed)
    d = rng.uniform(-7, 7, (rows, 3)).astype(dt)
    mask = (rng.uniform(0, 1, rows) > 0.3).astype(dt)
    d = d * mask[:, None]
    got = env_rows(jnp.asarray(d), jnp.asarray(mask))
    want = ref.env_rows_ref(jnp.asarray(d), jnp.asarray(mask))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **tol(dt))


def test_env_rows_masked_are_zero():
    d = np.zeros((8, 3))
    mask = np.zeros(8)
    got = np.asarray(env_rows(jnp.asarray(d), jnp.asarray(mask)))
    assert np.all(got == 0.0)


def test_env_rows_inside_smooth_region_is_inverse_r():
    d = np.array([[2.0, 0.0, 0.0]])
    mask = np.ones(1)
    got = np.asarray(env_rows(jnp.asarray(d), jnp.asarray(mask)))
    # s = 1/r = 0.5 inside the smooth region; s*x/r = 0.5 * 1.0 = 0.5
    np.testing.assert_allclose(got[0], [0.5, 0.5, 0.0, 0.0], rtol=1e-12)


def test_env_rows_beyond_cutoff_is_zero():
    d = np.array([[P.R_CUT + 0.5, 0.0, 0.0]])
    got = np.asarray(env_rows(jnp.asarray(d), jnp.asarray(np.ones(1))))
    np.testing.assert_allclose(got, 0.0, atol=1e-14)


def test_switch_is_c1_at_cutoffs():
    # numerically check continuity of s(r) and s'(r) at rcs and rc
    for r0 in (P.R_CUT_SMOOTH, P.R_CUT):
        eps = 1e-6
        f = lambda r: float(ref.switch_poly(jnp.asarray(r)))
        left = (f(r0) - f(r0 - eps)) / eps
        right = (f(r0 + eps) - f(r0)) / eps
        assert abs(f(r0 + eps) - f(r0 - eps)) < 1e-5
        assert abs(left - right) < 1e-4


# ----------------------------------------------------------------------------
# embedding kernel
# ----------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 40),
    s=st.sampled_from([P.SEL[0], P.SEL[1]]),
    seed=st.integers(0, 2**31 - 1),
    dt=st.sampled_from([np.float32, np.float64]),
    which=st.integers(0, 1),
)
def test_embedding_matches_ref(m, s, seed, dt, which):
    rng = np.random.RandomState(seed)
    sv = (rng.uniform(0, 1.2, (m, s)) * (rng.uniform(0, 1, (m, s)) > 0.4)).astype(dt)
    mlp = PRM.embed_dp[which]
    got = embedding_pallas(jnp.asarray(sv), mlp)
    want = ref.embedding_ref(jnp.asarray(sv), mlp)
    assert got.shape == (m, s, P.M1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **tol(dt))


def test_embedding_row_padding_is_exact():
    # row counts around the BLOCK boundary must not change results
    rng = np.random.RandomState(0)
    for rows in (BLOCK_ROWS - 1, BLOCK_ROWS, BLOCK_ROWS + 1):
        sv = rng.uniform(0, 1, (1, rows))
        got = embedding_pallas(jnp.asarray(sv), PRM.embed_dw[0])
        want = ref.embedding_ref(jnp.asarray(sv), PRM.embed_dw[0])
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-12, atol=1e-14
        )


# ----------------------------------------------------------------------------
# fitting kernel
# ----------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 2 * BLOCK_ROWS + 3),
    seed=st.integers(0, 2**31 - 1),
    dt=st.sampled_from([np.float32, np.float64]),
    which=st.integers(0, 1),
)
def test_fitting_matches_ref(m, seed, dt, which):
    rng = np.random.RandomState(seed)
    desc = (rng.standard_normal((m, P.DESC_DIM)) * 0.05).astype(dt)
    mlp = PRM.fit_dp[which]
    got = fitting_pallas(jnp.asarray(desc), mlp)
    want = ref.fitting_ref(jnp.asarray(desc), mlp)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), **tol(dt))


def test_fitting_dw_head_width():
    desc = jnp.zeros((3, P.DESC_DIM))
    out = fitting_pallas(desc, PRM.fit_dw)
    assert out.shape == (3, P.M1)
    want = ref.fitting_ref(desc, PRM.fit_dw)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-12)


# ----------------------------------------------------------------------------
# env_mat composite wrapper
# ----------------------------------------------------------------------------


def test_env_mat_pallas_matches_ref_on_water():
    from compile import testutil as TU

    coords, box = TU.water_box(8, seed=3)
    nl = TU.full_nlist(coords, box, 8)
    env_k, s_k = env_mat_pallas(jnp.asarray(coords), jnp.asarray(box), jnp.asarray(nl))
    env_r, s_r = ref.env_mat_ref(jnp.asarray(coords), jnp.asarray(box), jnp.asarray(nl))
    np.testing.assert_allclose(np.asarray(env_k), np.asarray(env_r), rtol=1e-12)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r), rtol=1e-12)


def test_env_rows_gradient_uses_ref_backward():
    # custom_vjp must agree with finite differences
    rng = np.random.RandomState(1)
    d = rng.uniform(-4, 4, (16, 3))
    mask = np.ones(16)
    f = lambda dd: jnp.sum(env_rows(dd, jnp.asarray(mask)) ** 2)
    g = jax.grad(f)(jnp.asarray(d))
    eps = 1e-6
    for k in [(0, 0), (5, 2), (11, 1)]:
        dp = d.copy()
        dp[k] += eps
        dm = d.copy()
        dm[k] -= eps
        fd = (float(f(jnp.asarray(dp))) - float(f(jnp.asarray(dm)))) / (2 * eps)
        assert abs(fd - float(g[k])) < 1e-5 * max(1.0, abs(fd))
