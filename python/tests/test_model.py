"""L2 correctness: the assembled DPLR model (energies, forces, symmetries)."""

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile import params as P
from compile import testutil as TU
from compile.kernels import ref

PRM = P.ModelParams.seeded()


def make_system(nmol, seed=7):
    coords, box = TU.water_box(nmol, seed=seed)
    nl = TU.full_nlist(coords, box, nmol)
    nlo = TU.o_nlist(coords, box, nmol)
    return (
        jnp.asarray(coords),
        jnp.asarray(box),
        jnp.asarray(nl),
        jnp.asarray(nlo),
    )


# ----------------------------------------------------------------------------
# energy / force consistency
# ----------------------------------------------------------------------------


def test_dp_ef_matches_ref_energy_and_grad():
    c, b, nl, _ = make_system(16)
    e_k, f_k = jax.jit(M.build_dp_ef(16, PRM))(c, b, nl)
    e_r, g_r = jax.value_and_grad(lambda cc: ref.dp_energy_ref(cc, b, nl, 16, PRM))(c)
    assert abs(float(e_k - e_r)) < 1e-9
    np.testing.assert_allclose(np.asarray(f_k), -np.asarray(g_r), atol=1e-10)


def test_forces_are_minus_finite_difference():
    c, b, nl, _ = make_system(8, seed=11)
    fn = jax.jit(M.build_dp_ef(8, PRM))
    e0, f = fn(c, b, nl)
    eps = 1e-6
    rng = np.random.RandomState(2)
    for _ in range(4):
        i = rng.randint(0, c.shape[0])
        k = rng.randint(0, 3)
        cp = np.asarray(c).copy()
        cp[i, k] += eps
        cm = np.asarray(c).copy()
        cm[i, k] -= eps
        ep, _ = fn(jnp.asarray(cp), b, nl)
        em, _ = fn(jnp.asarray(cm), b, nl)
        fd = -(float(ep) - float(em)) / (2 * eps)
        assert abs(fd - float(f[i, k])) < 1e-4 * max(1.0, abs(fd))


def test_net_force_is_zero():
    # translation invariance => sum of forces vanishes
    c, b, nl, _ = make_system(16, seed=5)
    _, f = jax.jit(M.build_dp_ef(16, PRM))(c, b, nl)
    np.testing.assert_allclose(np.asarray(jnp.sum(f, axis=0)), 0.0, atol=1e-8)


def test_energy_translation_invariance():
    c, b, nl, _ = make_system(8, seed=9)
    fn = jax.jit(M.build_dp_ef(8, PRM))
    e0, _ = fn(c, b, nl)
    shift = jnp.asarray([1.234, -0.77, 2.5])
    # note: nlist indices are unchanged by a rigid shift
    e1, _ = fn(c + shift, b, nl)
    assert abs(float(e0 - e1)) < 1e-9


# ----------------------------------------------------------------------------
# DW model: covariance and VJP
# ----------------------------------------------------------------------------


def rotation_matrix(seed=0):
    rng = np.random.RandomState(seed)
    q = rng.standard_normal(4)
    q /= np.linalg.norm(q)
    w, x, y, z = q
    return np.array(
        [
            [1 - 2 * (y * y + z * z), 2 * (x * y - w * z), 2 * (x * z + w * y)],
            [2 * (x * y + w * z), 1 - 2 * (x * x + z * z), 2 * (y * z - w * x)],
            [2 * (x * z - w * y), 2 * (y * z + w * x), 1 - 2 * (x * x + y * y)],
        ]
    )


def test_dw_delta_rotation_covariance():
    # rotate an *isolated* cluster (no PBC wrap): delta must co-rotate
    nmol = 6
    coords, _ = TU.water_box(nmol, seed=13)
    big = np.array([200.0, 200.0, 200.0])
    coords = coords + 60.0  # keep away from boundary
    nlo = TU.o_nlist(coords, big, nmol)
    rot = rotation_matrix(3)
    centre = coords.mean(axis=0)
    crot = (coords - centre) @ rot.T + centre
    d0 = np.asarray(
        ref.dw_delta_ref(jnp.asarray(coords), jnp.asarray(big), jnp.asarray(nlo), nmol, PRM)
    )
    d1 = np.asarray(
        ref.dw_delta_ref(jnp.asarray(crot), jnp.asarray(big), jnp.asarray(nlo), nmol, PRM)
    )
    np.testing.assert_allclose(d1, d0 @ rot.T, atol=1e-9)


def test_dw_delta_is_clamped():
    c, b, _, nlo = make_system(16, seed=21)
    d = np.asarray(ref.dw_delta_ref(c, b, nlo, 16, PRM))
    assert np.all(np.linalg.norm(d, axis=1) <= P.WC_CLAMP + 1e-12)


def test_dw_vjp_matches_autodiff():
    nmol = 8
    c, b, _, nlo = make_system(nmol, seed=4)
    fwc = jnp.asarray(np.random.RandomState(0).standard_normal((nmol, 3)) * 0.3)
    delta, fc = jax.jit(M.build_dw_vjp(nmol, PRM))(c, b, nlo, fwc)

    def wsum(cc):
        w = cc[:nmol] + ref.dw_delta_ref(cc, b, nlo, nmol, PRM)
        return jnp.sum(w * fwc)

    want = jax.grad(wsum)(c)
    np.testing.assert_allclose(np.asarray(fc), np.asarray(want), atol=1e-9)
    want_d = ref.dw_delta_ref(c, b, nlo, nmol, PRM)
    np.testing.assert_allclose(np.asarray(delta), np.asarray(want_d), atol=1e-10)


def test_dw_vjp_binding_term_identity():
    # with a frozen DW net output (zero gates far apart), f_contrib reduces
    # to scattering f_wc onto the binding O atoms; check the O-block rows
    # dominate accordingly for small fwc on a normal system.
    nmol = 8
    c, b, _, nlo = make_system(nmol, seed=8)
    fwc = jnp.ones((nmol, 3)) * 0.1
    _, fc = jax.jit(M.build_dw_vjp(nmol, PRM))(c, b, nlo, fwc)
    # total momentum transferred equals the total f_wc (sum over all atoms,
    # since dW/dR is a partition of unity under translation)
    np.testing.assert_allclose(
        np.asarray(jnp.sum(fc, axis=0)), np.asarray(jnp.sum(fwc, axis=0)), atol=1e-9
    )


# ----------------------------------------------------------------------------
# prior
# ----------------------------------------------------------------------------


def test_prior_minimum_near_equilibrium_geometry():
    # a single isolated water at ideal geometry has ~zero bond/angle energy
    nmol = 1
    r0, t0 = P.BOND_R0, P.ANGLE_T0
    c = np.zeros((3, 3))
    c[0] = [50, 50, 50]
    c[1] = c[0] + [r0 * np.cos(t0 / 2), r0 * np.sin(t0 / 2), 0]
    c[2] = c[0] + [r0 * np.cos(t0 / 2), -r0 * np.sin(t0 / 2), 0]
    box = np.array([100.0, 100.0, 100.0])
    nl = TU.full_nlist(c, box, nmol)
    e = float(ref.prior_energy_ref(jnp.asarray(c), jnp.asarray(box), jnp.asarray(nl), nmol))
    # only the intramolecular O-H / H-H Born-Mayer terms remain (~10.7 eV)
    assert 0.0 < e < 15.0
    # bond COMPRESSION must raise the energy (both the harmonic term and
    # the Born-Mayer repulsion resist it; stretching instead trades the
    # two off — the effective O-H minimum sits slightly beyond r0)
    c2 = c.copy()
    c2[1] = c[0] + 0.7 * (c[1] - c[0])
    e2 = float(ref.prior_energy_ref(jnp.asarray(c2), jnp.asarray(box), jnp.asarray(nl), nmol))
    assert e2 > e + 0.5


@settings(max_examples=8, deadline=None)
@given(nmol=st.sampled_from([4, 8, 16]), seed=st.integers(0, 1000))
def test_energy_finite_and_force_bounded(nmol, seed):
    coords, box = TU.water_box(nmol, seed=seed)
    nl = TU.full_nlist(coords, box, nmol)
    e, f = jax.jit(M.build_dp_ef(nmol, PRM))(
        jnp.asarray(coords), jnp.asarray(box), jnp.asarray(nl)
    )
    assert np.isfinite(float(e))
    assert np.all(np.isfinite(np.asarray(f)))
    assert float(jnp.max(jnp.abs(f))) < 1e3
